//! Struct-of-arrays storage for the shard's model-level work queues.
//!
//! At 100M-request scale the batch backlog holds millions of queued
//! [`WorkItem`]s at once. The AoS `VecDeque<WorkItem>` layout made the two
//! hot read patterns — peeking the front's `input_tokens`/`arrival` for
//! admission, and stride-sampling TTFT deadlines for `QueueStats` — walk
//! 104-byte records to touch 8 of those bytes. Here each hot scalar lives
//! in its own `VecDeque`, so deadline sampling streams a dense `f64` lane
//! and the queue's resident-set is dominated by what the simulation
//! actually reads.
//!
//! `pop_front` reconstructs the exact `WorkItem` that was pushed —
//! field-for-field, bit-for-bit — so the surrounding shard logic (and the
//! digest tests pinning it) cannot observe the layout change.

use std::collections::VecDeque;

use crate::core::{PhaseBreakdown, Request, RequestClass, RequestId, Slo, Time, WaitKind};
use crate::sim::instance::WorkItem;

/// A FIFO of [`WorkItem`]s stored column-wise. Supports exactly the
/// operations the shard queues need: FIFO push/pop, `push_front` for
/// eviction re-queues, front peeks, and indexed deadline reads.
#[derive(Debug, Default)]
pub struct WorkQueue {
    id: VecDeque<u64>,
    class: VecDeque<RequestClass>,
    slo_ttft: VecDeque<Time>,
    slo_itl: VecDeque<Time>,
    arrival: VecDeque<Time>,
    input_tokens: VecDeque<u32>,
    output_tokens: VecDeque<u32>,
    model: VecDeque<u32>,
    generated: VecDeque<f64>,
    ctx_done: VecDeque<u64>,
    first_token: VecDeque<Option<Time>>,
    last_emit: VecDeque<Time>,
    max_gap: VecDeque<Time>,
    preemptions: VecDeque<u32>,
    retries: VecDeque<u32>,
    kv_saved: VecDeque<bool>,
    wait_since: VecDeque<Time>,
    wait_kind: VecDeque<WaitKind>,
    phases: VecDeque<PhaseBreakdown>,
}

impl WorkQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id.is_empty()
    }

    pub fn push_back(&mut self, w: WorkItem) {
        self.id.push_back(w.req.id.0);
        self.class.push_back(w.req.class);
        self.slo_ttft.push_back(w.req.slo.ttft);
        self.slo_itl.push_back(w.req.slo.itl);
        self.arrival.push_back(w.req.arrival);
        self.input_tokens.push_back(w.req.input_tokens);
        self.output_tokens.push_back(w.req.output_tokens);
        self.model.push_back(w.req.model as u32);
        self.generated.push_back(w.generated);
        self.ctx_done.push_back(w.ctx_done);
        self.first_token.push_back(w.first_token);
        self.last_emit.push_back(w.last_emit);
        self.max_gap.push_back(w.max_gap);
        self.preemptions.push_back(w.preemptions);
        self.retries.push_back(w.retries);
        self.kv_saved.push_back(w.kv_saved);
        self.wait_since.push_back(w.wait_since);
        self.wait_kind.push_back(w.wait_kind);
        self.phases.push_back(w.phases);
    }

    /// Re-queue at the head (evictions go back to the front so preempted
    /// work keeps its place).
    pub fn push_front(&mut self, w: WorkItem) {
        self.id.push_front(w.req.id.0);
        self.class.push_front(w.req.class);
        self.slo_ttft.push_front(w.req.slo.ttft);
        self.slo_itl.push_front(w.req.slo.itl);
        self.arrival.push_front(w.req.arrival);
        self.input_tokens.push_front(w.req.input_tokens);
        self.output_tokens.push_front(w.req.output_tokens);
        self.model.push_front(w.req.model as u32);
        self.generated.push_front(w.generated);
        self.ctx_done.push_front(w.ctx_done);
        self.first_token.push_front(w.first_token);
        self.last_emit.push_front(w.last_emit);
        self.max_gap.push_front(w.max_gap);
        self.preemptions.push_front(w.preemptions);
        self.retries.push_front(w.retries);
        self.kv_saved.push_front(w.kv_saved);
        self.wait_since.push_front(w.wait_since);
        self.wait_kind.push_front(w.wait_kind);
        self.phases.push_front(w.phases);
    }

    /// Reassemble the item at `i` exactly as pushed (checkpoint encode and
    /// pop both go through here).
    pub fn item(&self, i: usize) -> WorkItem {
        WorkItem {
            req: Request {
                id: RequestId(self.id[i]),
                class: self.class[i],
                slo: Slo {
                    ttft: self.slo_ttft[i],
                    itl: self.slo_itl[i],
                },
                arrival: self.arrival[i],
                input_tokens: self.input_tokens[i],
                output_tokens: self.output_tokens[i],
                model: self.model[i] as usize,
            },
            generated: self.generated[i],
            ctx_done: self.ctx_done[i],
            first_token: self.first_token[i],
            last_emit: self.last_emit[i],
            max_gap: self.max_gap[i],
            preemptions: self.preemptions[i],
            retries: self.retries[i],
            kv_saved: self.kv_saved[i],
            wait_since: self.wait_since[i],
            wait_kind: self.wait_kind[i],
            phases: self.phases[i],
        }
    }

    pub fn pop_front(&mut self) -> Option<WorkItem> {
        let id = self.id.pop_front()?;
        Some(WorkItem {
            req: Request {
                id: RequestId(id),
                class: self.class.pop_front().unwrap(),
                slo: Slo {
                    ttft: self.slo_ttft.pop_front().unwrap(),
                    itl: self.slo_itl.pop_front().unwrap(),
                },
                arrival: self.arrival.pop_front().unwrap(),
                input_tokens: self.input_tokens.pop_front().unwrap(),
                output_tokens: self.output_tokens.pop_front().unwrap(),
                model: self.model.pop_front().unwrap() as usize,
            },
            generated: self.generated.pop_front().unwrap(),
            ctx_done: self.ctx_done.pop_front().unwrap(),
            first_token: self.first_token.pop_front().unwrap(),
            last_emit: self.last_emit.pop_front().unwrap(),
            max_gap: self.max_gap.pop_front().unwrap(),
            preemptions: self.preemptions.pop_front().unwrap(),
            retries: self.retries.pop_front().unwrap(),
            kv_saved: self.kv_saved.pop_front().unwrap(),
            wait_since: self.wait_since.pop_front().unwrap(),
            wait_kind: self.wait_kind.pop_front().unwrap(),
            phases: self.phases.pop_front().unwrap(),
        })
    }

    /// `input_tokens` of the head item (KV-admission peek) — one lane, no
    /// record walk.
    pub fn front_input_tokens(&self) -> Option<u32> {
        self.input_tokens.front().copied()
    }

    /// Arrival time of the head item (head-of-line wait).
    pub fn front_arrival(&self) -> Option<Time> {
        self.arrival.front().copied()
    }

    /// TTFT deadline of the item at `i` (`arrival + slo.ttft`) — the
    /// `QueueStats` stride-sampling read, now two dense `f64` lanes.
    pub fn ttft_deadline(&self, i: usize) -> Time {
        self.arrival[i] + self.slo_ttft[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, class: RequestClass, arrival: f64) -> WorkItem {
        let mut w = WorkItem::fresh(Request {
            id: RequestId(id),
            class,
            slo: match class {
                RequestClass::Interactive => Slo::interactive_default(),
                RequestClass::Batch => Slo::batch_default(),
            },
            arrival,
            input_tokens: 32 + id as u32,
            output_tokens: 100 + id as u32,
            model: (id % 3) as usize,
        });
        // Exercise the non-fresh fields too.
        w.generated = id as f64 * 0.5;
        w.ctx_done = id * 7;
        w.first_token = if id % 2 == 0 { Some(arrival + 0.1) } else { None };
        w.max_gap = 0.01 * id as f64;
        w.preemptions = id as u32 % 4;
        w.retries = id as u32 % 2;
        w.kv_saved = id % 3 == 0;
        w.wait_since = arrival + 0.125 * (id % 5) as f64;
        w.wait_kind = WaitKind::from_u8((id % 4) as u8);
        w.phases.queue_wait = 0.3 * id as f64;
        w.phases.retry_rework = if id % 2 == 1 { 1.5 } else { 0.0 };
        w
    }

    fn assert_same(a: &WorkItem, b: &WorkItem) {
        assert_eq!(a.req.id, b.req.id);
        assert_eq!(a.req.class, b.req.class);
        assert_eq!(a.req.slo.ttft.to_bits(), b.req.slo.ttft.to_bits());
        assert_eq!(a.req.slo.itl.to_bits(), b.req.slo.itl.to_bits());
        assert_eq!(a.req.arrival.to_bits(), b.req.arrival.to_bits());
        assert_eq!(a.req.input_tokens, b.req.input_tokens);
        assert_eq!(a.req.output_tokens, b.req.output_tokens);
        assert_eq!(a.req.model, b.req.model);
        assert_eq!(a.generated.to_bits(), b.generated.to_bits());
        assert_eq!(a.ctx_done, b.ctx_done);
        assert_eq!(
            a.first_token.map(f64::to_bits),
            b.first_token.map(f64::to_bits)
        );
        assert_eq!(a.last_emit.to_bits(), b.last_emit.to_bits());
        assert_eq!(a.max_gap.to_bits(), b.max_gap.to_bits());
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.kv_saved, b.kv_saved);
        assert_eq!(a.wait_since.to_bits(), b.wait_since.to_bits());
        assert_eq!(a.wait_kind, b.wait_kind);
        assert_eq!(a.phases.queue_wait.to_bits(), b.phases.queue_wait.to_bits());
        assert_eq!(
            a.phases.retry_rework.to_bits(),
            b.phases.retry_rework.to_bits()
        );
    }

    #[test]
    fn fifo_matches_vecdeque_reference_bit_for_bit() {
        let mut soa = WorkQueue::new();
        let mut aos: VecDeque<WorkItem> = VecDeque::new();
        // Interleave push_back / push_front / pop_front like the shard does
        // (arrivals back, evictions front, dispatch pops).
        for id in 0..200u64 {
            let w = item(
                id,
                if id % 4 == 0 {
                    RequestClass::Interactive
                } else {
                    RequestClass::Batch
                },
                id as f64 * 0.25,
            );
            if id % 5 == 3 {
                soa.push_front(w.clone());
                aos.push_front(w);
            } else {
                soa.push_back(w.clone());
                aos.push_back(w);
            }
            if id % 3 == 2 {
                let a = soa.pop_front().unwrap();
                let b = aos.pop_front().unwrap();
                assert_same(&a, &b);
            }
        }
        assert_eq!(soa.len(), aos.len());
        while let Some(b) = aos.pop_front() {
            assert_same(&soa.pop_front().unwrap(), &b);
        }
        assert!(soa.is_empty());
        assert!(soa.pop_front().is_none());
    }

    #[test]
    fn peeks_and_indexed_deadlines_agree_with_items() {
        let mut q = WorkQueue::new();
        for id in 0..20u64 {
            q.push_back(item(id, RequestClass::Batch, 10.0 + id as f64));
        }
        assert_eq!(q.front_input_tokens(), Some(32));
        assert_eq!(q.front_arrival(), Some(10.0));
        for i in (0..q.len()).step_by(3) {
            let w = q.item(i);
            assert_eq!(q.ttft_deadline(i), w.req.ttft_deadline());
        }
        let w5 = q.item(5);
        assert_eq!(w5.req.id.0, 5);
        assert_eq!(q.len(), 20, "item() must not consume");
    }
}
