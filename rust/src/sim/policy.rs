//! The autoscaling-policy interface the simulator (and the real server)
//! drive, split along the paper's hierarchy:
//!
//!  - [`LocalPolicy`] — the per-model half: request placement (`route` /
//!    `pull_order`) and the per-instance batch-size autoscaler (`on_step`).
//!    One instance exists per model, owns only per-model state, and runs
//!    inside that model's event-loop shard (`sim::shard::ModelShard`) — so
//!    it must be `Send` and must only read the [`ModelView`] it is handed.
//!  - [`GlobalPolicy`] — the cross-model half: `bootstrap` and the periodic
//!    `autoscale` over the merged [`ClusterView`], plus the completion
//!    observations (`on_complete`) that feed its estimators. It runs only
//!    at tick barriers on the driver thread and manufactures the local
//!    halves via `make_local`.
//!
//! Chiron (`coordinator::chiron`) and all baselines (`baselines::*`)
//! implement the pair. `Policy` remains as an alias for [`GlobalPolicy`] so
//! `Box<dyn Policy>` call sites (experiments, config, examples) read
//! unchanged.

use crate::core::{InstanceClass, InstanceId, ModelSpec, Request, RequestClass, Time};

/// Lifecycle state of a serving instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InstanceState {
    /// Model weights loading; becomes Running at `ready_at`.
    Loading { ready_at: Time },
    Running,
    /// No new admissions; retires when the running set drains.
    Draining,
    /// Crashed at `at` (fault injection): all in-flight work was evicted
    /// with KV lost; the shard retires the instance and the driver frees
    /// its GPUs at the next tick barrier, charged only up to `at`.
    Failed { at: Time },
}

/// Read-only per-instance snapshot handed to policies. Plain scalar data —
/// `Copy`, heap-free — so snapshots live on the stack and cached views are
/// patched in place by the simulator.
#[derive(Debug, Clone, Copy)]
pub struct InstanceView {
    pub id: InstanceId,
    pub class: InstanceClass,
    pub model: usize,
    pub state: InstanceState,
    /// Requests currently decoding.
    pub running: u32,
    /// Of which interactive.
    pub running_interactive: u32,
    /// Requests admitted but waiting in the instance-local queue.
    pub waiting: u32,
    pub max_batch: u32,
    pub kv_tokens: u64,
    pub kv_capacity: u64,
    /// Duration of the most recent engine step (the observed ITL).
    pub last_step_time: Time,
    /// Decode-only component of the most recent step (batch-dependent ITL;
    /// excludes chunked-prefill time — see coordinator::local).
    pub last_decode_time: Time,
    /// EWMA decode-token throughput (tokens/s).
    pub throughput_tokens: f64,
    /// Tightest ITL SLO among running requests (paper §4.2: the instance's
    /// operative ITL SLO); f64::INFINITY when idle.
    pub min_itl_slo: Time,
    /// Completed engine steps (local-autoscaler invocations so far).
    pub steps: u64,
}

impl InstanceView {
    pub fn is_running(&self) -> bool {
        self.state == InstanceState::Running
    }

    pub fn kv_headroom(&self) -> u64 {
        self.kv_capacity.saturating_sub(self.kv_tokens)
    }

    /// Free running slots under the current max batch.
    pub fn slot_headroom(&self) -> u32 {
        self.max_batch
            .saturating_sub(self.running + self.waiting)
    }

    pub fn has_interactive(&self) -> bool {
        self.running_interactive > 0
    }
}

/// Summary of one queued request (the policy never sees ground-truth output
/// lengths).
#[derive(Debug, Clone)]
pub struct QueuedReq {
    pub id: crate::core::RequestId,
    pub class: RequestClass,
    pub model: usize,
    pub arrival: Time,
    pub ttft_deadline: Time,
    pub itl_slo: Time,
    pub input_tokens: u32,
}

impl QueuedReq {
    pub fn from_request(r: &Request) -> Self {
        QueuedReq {
            id: r.id,
            class: r.class,
            model: r.model,
            arrival: r.arrival,
            ttft_deadline: r.ttft_deadline(),
            itl_slo: r.slo.itl,
            input_tokens: r.input_tokens,
        }
    }
}

/// Summary of one model's global queue. Policies never see ground-truth
/// output lengths; for large queues (the W_B evaluation reaches 700k batch
/// requests) the deadline list is a uniform FCFS-ordered sample with a
/// recorded stride so estimators can scale counts back up.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub batch_len: usize,
    pub interactive_len: usize,
    pub batch_oldest_arrival: Option<Time>,
    /// Uniform sample of batch-queue TTFT deadlines in FCFS order.
    pub batch_deadline_sample: Vec<Time>,
    /// Each sampled deadline represents `stride` queued requests.
    pub stride: usize,
    /// Cumulative arrivals this model has received as of the barrier (all
    /// classes). Predictive policies difference successive barriers to
    /// recover per-epoch arrival counts — the observation stream online
    /// rate forecasters (`crate::forecast`) are fed with.
    pub arrived_total: u64,
    /// Of which interactive-class arrivals.
    pub arrived_interactive: u64,
    /// Cumulative crash-evicted requests that exhausted their retry budget
    /// (terminal failures). Zero in fault-free runs.
    pub failed_total: u64,
    /// Cumulative batch arrivals shed by the overload knob
    /// (`FaultSpec::shed_queue_len`). Zero in fault-free runs.
    pub shed_total: u64,
    /// Cumulative crash-eviction re-queues (each bumps one request's retry
    /// count). Zero in fault-free runs.
    pub retried_total: u64,
}

/// Read-only snapshot of one model's slice of the cluster, handed to
/// [`LocalPolicy`] calls between tick barriers. `instances` holds only this
/// model's instances, so per-event routing never observes (or depends on)
/// other shards' mid-epoch state — the structural guarantee that makes
/// shard parallelism bit-identical to a sequential run.
#[derive(Debug)]
pub struct ModelView<'a> {
    pub now: Time,
    /// The model index this view covers.
    pub model: usize,
    /// This model's instances (every view's `model` equals `self.model`).
    pub instances: &'a [InstanceView],
}

/// Read-only cluster snapshot. Only materialized at tick barriers, where
/// the epoch driver merges every shard's instance views and queue summaries
/// for the global autoscaler.
#[derive(Debug)]
pub struct ClusterView<'a> {
    pub now: Time,
    pub instances: &'a [InstanceView],
    /// Per-model global-queue summaries.
    pub queues: &'a [QueueStats],
    pub models: &'a [ModelSpec],
    pub gpus_total: u32,
    pub gpus_used: u32,
}

impl<'a> ClusterView<'a> {
    pub fn gpus_free(&self) -> u32 {
        self.gpus_total.saturating_sub(self.gpus_used)
    }

    /// Can another instance of `model` fit in the GPU budget?
    pub fn can_fit(&self, model: usize) -> bool {
        self.models[model].gpus_per_instance <= self.gpus_free()
    }

    pub fn instances_of(&self, model: usize) -> impl Iterator<Item = &InstanceView> {
        self.instances.iter().filter(move |i| i.model == model)
    }

    pub fn queue_len_batch(&self, model: usize) -> usize {
        self.queues[model].batch_len
    }
}

/// Global-autoscaler actions.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    AddInstance { model: usize, class: InstanceClass },
    /// Graceful removal: stop admissions, retire when drained.
    RemoveInstance { id: InstanceId },
    /// Reclassify a running instance (Chiron converts mixed↔interactive as
    /// over-provisioning shifts).
    SetClass { id: InstanceId, class: InstanceClass },
}

impl Action {
    /// Short human-readable form, used by the decision audit
    /// (`telemetry::DecisionRecord::action`) and `chiron explain`.
    pub fn describe(&self) -> String {
        match self {
            Action::AddInstance { class, .. } => format!("add {}", class.as_str()),
            Action::RemoveInstance { id } => format!("remove {id}"),
            Action::SetClass { id, class } => format!("set-class {id} {}", class.as_str()),
        }
    }
}

/// Routing decision for a newly arrived (or re-queued) request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Route {
    /// Send to this instance's local queue now.
    Dispatch(InstanceId),
    /// Keep in the global queue (batch requests awaiting capacity).
    Queue,
}

/// The per-model (local) half of an autoscaling policy. Owned by one
/// model's event-loop shard and driven between tick barriers; `Send` so
/// shards can run on the persistent worker pool.
pub trait LocalPolicy: Send {
    /// Route a request at arrival (or when re-queued after eviction).
    /// Sees only its own model's instances.
    fn route(&mut self, req: &QueuedReq, view: &ModelView) -> Route;

    /// Which global queues may `inst` pull from when it has headroom, in
    /// priority order. Returns a static slice: this runs after every engine
    /// step, and per-call `Vec`s were measurable allocator traffic.
    fn pull_order(&self, inst: &InstanceView) -> &'static [RequestClass];

    /// Local autoscaler (paper Algorithm 1): called after each engine step
    /// of `inst`; returns the new max batch size if it should change.
    fn on_step(&mut self, inst: &InstanceView, now: Time) -> Option<u32>;

    /// Checkpoint hook: serialize mutable policy state into `out`. Stateless
    /// policies (the default) write nothing; a policy with estimator or
    /// decision state must override both hooks for `--resume` to be
    /// bit-identical.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Checkpoint hook: restore state written by
    /// [`save_state`](Self::save_state).
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The cross-model (global) half of an autoscaling policy: bootstrap and
/// the periodic instance autoscaler, invoked only at tick barriers over the
/// merged cluster snapshot.
pub trait GlobalPolicy {
    fn name(&self) -> &str;

    /// The fixed `&'static` form of [`name`](Self::name), when the policy
    /// has one. `SimReport::finish` borrows it instead of re-allocating the
    /// name per run; policies with composed names (e.g. the predictive
    /// scaler's `inner+estimator`) keep the owned fallback.
    fn static_name(&self) -> Option<&'static str> {
        None
    }

    /// Build the per-model local half. Called once per model when a
    /// simulation (or server) starts; all per-model routing/batch state
    /// lives in the returned object.
    fn make_local(&self, model: usize) -> Box<dyn LocalPolicy>;

    /// Global autoscaler: called on each tick; returns scaling actions.
    fn autoscale(&mut self, view: &ClusterView) -> Vec<Action>;

    /// Initial cluster composition before the trace starts.
    fn bootstrap(&mut self, view: &ClusterView) -> Vec<Action>;

    /// Initial max batch size for a newly added instance.
    fn initial_max_batch(&self, _model: &ModelSpec, _class: InstanceClass) -> u32 {
        8
    }

    /// Completion observation: lets estimators fit output-length statistics
    /// from observed completions (QLM-style), never from ground truth.
    /// Shards record completions as they happen; the driver replays them
    /// here — per-model order preserved — before each `autoscale` call.
    fn on_complete(&mut self, _outcome: &crate::core::RequestOutcome) {}

    /// Per-model forecast-accuracy scores. Only predictive policies
    /// (`crate::forecast::PredictiveScaler`) return entries; the simulator
    /// collects them into `SimReport::forecast` at the end of a run.
    fn forecast_scores(&self) -> Vec<crate::forecast::ForecastScore> {
        Vec::new()
    }

    /// Enable/disable the decision audit (`telemetry::AuditLog`). Policies
    /// that do not record decisions ignore this — the default keeps every
    /// existing implementation compiling and auditing nothing.
    fn set_audit(&mut self, _on: bool) {}

    /// Drain decision records accumulated since the last drain. The driver
    /// calls this right after each `bootstrap`/`autoscale` and stamps every
    /// record with the barrier time (policies only know time through the
    /// view they are handed).
    fn drain_decisions(&mut self) -> Vec<crate::telemetry::DecisionRecord> {
        Vec::new()
    }

    /// Checkpoint hook: serialize mutable global state (estimators,
    /// output-length statistics) into `out`. Stateless policies write
    /// nothing. Checkpointed runs are restricted to policies that implement
    /// the pair faithfully (see `--resume` validation in the CLI).
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Checkpoint hook: restore state written by
    /// [`save_state`](Self::save_state).
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Compat alias: the pre-split trait name. `Box<dyn Policy>` is the global
/// half (which carries the `make_local` factory for the rest).
pub use self::GlobalPolicy as Policy;

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(running: u32, waiting: u32, max_batch: u32) -> InstanceView {
        InstanceView {
            id: InstanceId(0),
            class: InstanceClass::Mixed,
            model: 0,
            state: InstanceState::Running,
            running,
            running_interactive: 0,
            waiting,
            max_batch,
            kv_tokens: 100,
            kv_capacity: 1000,
            last_step_time: 0.05,
            last_decode_time: 0.05,
            throughput_tokens: 100.0,
            min_itl_slo: 0.2,
            steps: 1,
        }
    }

    #[test]
    fn headroom_math() {
        let i = inst(3, 2, 8);
        assert_eq!(i.slot_headroom(), 3);
        assert_eq!(i.kv_headroom(), 900);
        let full = inst(6, 2, 8);
        assert_eq!(full.slot_headroom(), 0);
        let over = inst(9, 2, 8);
        assert_eq!(over.slot_headroom(), 0); // saturates
    }

    #[test]
    fn loading_is_not_running() {
        let mut i = inst(0, 0, 8);
        i.state = InstanceState::Loading { ready_at: 5.0 };
        assert!(!i.is_running());
    }
}
