//! Thread-based serving front-end for the real engine.
//!
//! The engine loop runs on a worker thread; clients submit requests through
//! a channel and poll completions. A pluggable batch-size controller hook
//! lets the end-to-end example drive the engine with the same
//! `coordinator::LocalAutoscaler` the simulator uses (no HTTP stack is
//! available offline; `examples/quickstart.rs` exposes a line-protocol TCP
//! front-end on top of this).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::{EngineOutcome, EngineRequest, EngineStats, LlmEngine};

/// Batch-size controller callback: observes engine stats after each step and
/// may return a new max batch.
pub type BatchController = Box<dyn FnMut(&EngineStats) -> Option<usize> + Send>;

/// Handle to a running serving front-end.
pub struct ServingFrontend {
    tx: Sender<EngineRequest>,
    outcomes: Arc<Mutex<Vec<EngineOutcome>>>,
    stats: Arc<Mutex<EngineStats>>,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl ServingFrontend {
    /// Spawn the engine loop on a worker thread. The engine is constructed
    /// *inside* the worker via `factory` because PJRT handles (`xla` crate)
    /// are not `Send` — the executables never leave the thread that
    /// compiled them.
    pub fn start<F>(factory: F, mut controller: Option<BatchController>) -> Self
    where
        F: FnOnce() -> Result<LlmEngine> + Send + 'static,
    {
        let (tx, rx): (Sender<EngineRequest>, Receiver<EngineRequest>) = channel();
        let outcomes = Arc::new(Mutex::new(Vec::new()));
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let shutdown = Arc::new(AtomicBool::new(false));

        let out_c = outcomes.clone();
        let stats_c = stats.clone();
        let stop = shutdown.clone();
        let handle = std::thread::spawn(move || -> Result<()> {
            let mut engine = factory()?;
            *stats_c.lock().unwrap() = engine.stats();
            loop {
                // Drain the submission channel.
                loop {
                    match rx.try_recv() {
                        Ok(req) => engine.submit(req),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            stop.store(true, Ordering::SeqCst);
                            break;
                        }
                    }
                }
                if engine.is_idle() {
                    if stop.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                let done = engine.step()?;
                let st = engine.stats();
                if let Some(ctrl) = controller.as_mut() {
                    if let Some(mb) = ctrl(&st) {
                        engine.max_batch = mb.max(1);
                    }
                }
                *stats_c.lock().unwrap() = st;
                if !done.is_empty() {
                    out_c.lock().unwrap().extend(done);
                }
            }
        });

        ServingFrontend {
            tx,
            outcomes,
            stats,
            shutdown,
            handle: Some(handle),
        }
    }

    pub fn submit(&self, req: EngineRequest) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("engine thread terminated"))
    }

    /// Take completed outcomes accumulated so far.
    pub fn take_outcomes(&self) -> Vec<EngineOutcome> {
        std::mem::take(&mut *self.outcomes.lock().unwrap())
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    /// Wait until `n` outcomes have accumulated (or timeout), then take them.
    pub fn wait_for(&self, n: usize, timeout: std::time::Duration) -> Vec<EngineOutcome> {
        let start = std::time::Instant::now();
        loop {
            {
                let got = self.outcomes.lock().unwrap();
                if got.len() >= n {
                    break;
                }
            }
            if start.elapsed() > timeout {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        self.take_outcomes()
    }

    /// Signal shutdown and join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("engine thread panicked")),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for ServingFrontend {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
