//! Fault-plane integration tests: crash recovery, retry budgets, load
//! shedding, capacity reclamation, stragglers, and the Loading-removal
//! edge — all pinned to the simulator's two standing contracts:
//!
//!  1. **Conservation** — every arrival is accounted exactly once as
//!     completed, terminally failed, or shed; nothing is silently dropped.
//!  2. **Determinism** — fault runs are FNV-digest bit-identical at any
//!     `--shards` worker count and any `--jobs` grid width.

mod common;

use chiron::core::{InstanceClass, InstanceId, ModelSpec, RequestClass};
use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::sim::policy::{
    Action, ClusterView, GlobalPolicy, InstanceState, InstanceView, LocalPolicy, ModelView,
    QueuedReq, Route,
};
use chiron::sim::{run_sim, SimConfig, SimReport};
use chiron::util::parallel::run_grid_jobs;
use chiron::util::rng::Rng;
use chiron::workload::scenario::by_name;
use chiron::workload::trace::{workload_a, workload_b_batch};
use chiron::workload::{CrashEvent, FaultSpec, Reclamation, StragglerEvent, TraceBuilder};

use crate::common::digest_report as digest;

/// Every arrival accounted exactly once: completed outcomes + terminal
/// failures + shed arrivals must cover the trace, with nothing unfinished.
fn assert_conserved(r: &SimReport, label: &str) {
    assert_eq!(
        r.outcomes.len() + r.failed + r.shed,
        r.total_requests,
        "{label}: completed {} + failed {} + shed {} must equal arrivals {}",
        r.outcomes.len(),
        r.failed,
        r.shed,
        r.total_requests
    );
    assert_eq!(r.unfinished, 0, "{label}: no request may be left in limbo");
}

/// An interactive+batch workload on one llama8b pool with the given faults.
fn run_faulty(
    faults: FaultSpec,
    gpus: u32,
    n_inter: usize,
    n_batch: usize,
    workers: usize,
    record: bool,
) -> SimReport {
    let models = vec![ModelSpec::llama8b()];
    let mut rng = Rng::new(9);
    let trace = TraceBuilder::new()
        .stream(workload_a(10.0, n_inter, 0))
        .stream(workload_b_batch(n_batch, 5.0, 0, 1800.0))
        .build(&mut rng);
    let mut cfg = SimConfig::new(gpus, models.clone());
    cfg.max_sim_time = 4.0 * 3600.0;
    cfg.shard_workers = workers;
    cfg.record_gpu_trace = record;
    cfg.faults = faults;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim(cfg, trace, p.as_mut())
}

#[test]
fn crash_recovery_requeues_evicted_work_and_conserves_requests() {
    let faults = FaultSpec {
        seed: 17,
        crashes: vec![
            CrashEvent { model: 0, at: 30.0 },
            CrashEvent { model: 0, at: 60.0 },
            CrashEvent { model: 0, at: 90.0 },
        ],
        mtbf: Some(400.0),
        ..FaultSpec::default()
    };
    let r = run_faulty(faults.clone(), 16, 300, 1200, 1, false);
    assert!(
        r.retries > 0,
        "crashes mid-backlog must evict and re-queue in-flight work"
    );
    assert_conserved(&r, "crash recovery");

    // Bit-identical at any shard worker count, including the fault RNG.
    let r4 = run_faulty(faults, 16, 300, 1200, 4, false);
    assert_eq!(digest(&r), digest(&r4), "fault run: shards 1 vs 4");

    // And the fault plane genuinely changed the run.
    let clean = run_faulty(FaultSpec::default(), 16, 300, 1200, 1, false);
    assert_eq!(clean.failed + clean.shed, 0);
    assert_eq!(clean.retries, 0, "a default FaultSpec must stay inert");
    assert_ne!(digest(&r), digest(&clean));
}

#[test]
fn exhausted_retry_budget_counts_terminal_failures() {
    // A zero retry budget turns every crash eviction into a terminal
    // failure — counted, never silently dropped.
    let faults = FaultSpec {
        seed: 5,
        crashes: vec![
            CrashEvent { model: 0, at: 30.0 },
            CrashEvent { model: 0, at: 45.0 },
        ],
        mtbf: Some(150.0),
        max_retries: 0,
        ..FaultSpec::default()
    };
    let r = run_faulty(faults, 16, 300, 1200, 1, false);
    assert!(
        r.failed > 0,
        "with max_retries = 0, crash evictions must become terminal failures"
    );
    assert_conserved(&r, "retry budget");
}

#[test]
fn shedding_caps_the_batch_queue_and_spares_interactive() {
    let faults = FaultSpec {
        seed: 3,
        shed_queue_len: Some(50),
        ..FaultSpec::default()
    };
    let n_inter = 200;
    let r = run_faulty(faults, 16, n_inter, 1200, 1, false);
    assert!(
        r.shed > 0,
        "a 1200-request burst against a 50-deep queue bound must shed"
    );
    assert_conserved(&r, "shedding");
    // Shedding is batch-only: every interactive arrival still completes.
    let inter_done = r
        .outcomes
        .iter()
        .filter(|o| o.class == RequestClass::Interactive)
        .count();
    assert_eq!(inter_done, n_inter, "interactive requests are never shed");
}

#[test]
fn reclamation_dips_the_budget_at_barriers_only() {
    let total = 16u32;
    let reclaimed = 10u32;
    let cap = total - reclaimed;
    let faults = FaultSpec {
        seed: 7,
        reclamations: vec![Reclamation {
            start: 30.0,
            end: 300.0,
            gpus: reclaimed,
        }],
        ..FaultSpec::default()
    };
    let r = run_faulty(faults.clone(), total, 400, 800, 1, true);
    assert_conserved(&r, "reclamation");
    // Budget changes only at integral tick barriers, faults included.
    for &(t, used) in &r.gpu_trace {
        assert_eq!(t.fract(), 0.0, "budget changed between barriers at t={t}");
        assert!(used <= total, "budget must never exceed the cluster");
    }
    // The dip lands at the first barrier of the window: the last change at
    // or before t=30 leaves usage within the reclaimed cap (intermediate
    // same-timestamp entries record the instance-by-instance force-crash),
    // and every change strictly inside the window respects it.
    assert!(
        r.gpu_trace.iter().any(|&(t, u)| t <= 30.0 && u > cap),
        "the cluster should exceed {cap} GPUs before the window (dip non-vacuous)"
    );
    let at_window_start = r
        .gpu_trace
        .iter()
        .rev()
        .find(|(t, _)| *t <= 30.0)
        .expect("a change at or before the window start");
    assert!(
        at_window_start.1 <= cap,
        "usage {} must fit the reclaimed budget {cap} at the window start",
        at_window_start.1
    );
    for &(t, used) in &r.gpu_trace {
        if t > 30.0 && t < 300.0 {
            assert!(
                used <= cap,
                "t={t}: usage {used} exceeds reclaimed budget {cap}"
            );
        }
    }
    // gpu_seconds stays the exact occupancy integral: it can only credit
    // (mid-epoch retirements and crashes), never exceed the barrier trace.
    let mut integral = 0.0;
    for w in r.gpu_trace.windows(2) {
        integral += w[0].1 as f64 * (w[1].0 - w[0].0);
    }
    if let Some(&(t, used)) = r.gpu_trace.last() {
        integral += used as f64 * (r.end_time - t);
    }
    assert!(r.gpu_seconds > 0.0);
    assert!(
        r.gpu_seconds <= integral + 1e-6,
        "gpu_seconds {} must not exceed the barrier-quantized integral {integral}",
        r.gpu_seconds
    );
    // Deterministic across shard workers, reclamation crashes included.
    let r4 = run_faulty(faults, total, 400, 800, 4, true);
    assert_eq!(digest(&r), digest(&r4), "reclamation run: shards 1 vs 4");
    assert_eq!(r.gpu_trace, r4.gpu_trace);
}

#[test]
fn straggler_slows_a_single_instance_run() {
    // One GPU → one instance → the straggler window covers every step.
    let faults = FaultSpec {
        seed: 2,
        stragglers: vec![StragglerEvent {
            model: 0,
            start: 0.0,
            end: 1.0e9,
            factor: 4.0,
        }],
        ..FaultSpec::default()
    };
    let slow = run_faulty(faults, 1, 100, 0, 1, false);
    let clean = run_faulty(FaultSpec::default(), 1, 100, 0, 1, false);
    assert_conserved(&slow, "straggler");
    assert_conserved(&clean, "straggler control");
    assert!(
        slow.end_time > clean.end_time,
        "4x slower steps must finish later ({} vs {})",
        slow.end_time,
        clean.end_time
    );
    assert_ne!(digest(&slow), digest(&clean));
}

#[test]
fn fault_catalog_conserves_and_is_jobs_deterministic() {
    // The three catalog fault scenarios, run as a grid: conservation holds
    // per cell, and the grid digests are byte-identical at --jobs 1 and 4.
    let names = ["crash-midrush", "spot-reclaim", "straggler-tail"];
    let cell = |name: &str| -> SimReport {
        let spec = by_name(name).expect("catalog scenario").scaled(0.02);
        let models = spec.model_specs().unwrap();
        let mut cfg = SimConfig::new(spec.gpus, models.clone());
        cfg.max_sim_time = spec.max_time;
        cfg.faults = spec.faults.clone();
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        chiron::sim::run_sim_source(cfg, Box::new(spec.source(11)), p.as_mut())
    };
    for name in names {
        let r = cell(name);
        assert!(!r.outcomes.is_empty(), "{name}: work must complete");
        assert_conserved(&r, name);
    }
    let grid =
        |jobs: usize| run_grid_jobs(jobs, names.to_vec(), |_, name| digest(&cell(name)));
    let serial = grid(1);
    assert_eq!(
        serial,
        grid(4),
        "--jobs 1 and --jobs 4 fault grids must be byte-identical"
    );
}

/// Scripted policy for the Loading-removal edge: bootstrap one instance,
/// add a second at the first tick, then remove it (and reclassify the
/// survivor) while both are still Loading (llama8b load_time = 15 s).
struct ScriptedLocal;

impl LocalPolicy for ScriptedLocal {
    fn route(&mut self, _req: &QueuedReq, _view: &ModelView) -> Route {
        Route::Queue
    }
    fn pull_order(&self, _inst: &InstanceView) -> &'static [RequestClass] {
        &[RequestClass::Interactive, RequestClass::Batch]
    }
    fn on_step(&mut self, _inst: &InstanceView, _now: f64) -> Option<u32> {
        None
    }
}

struct ScriptedGlobal {
    ticks: u32,
}

impl GlobalPolicy for ScriptedGlobal {
    fn name(&self) -> &str {
        "scripted"
    }
    fn make_local(&self, _model: usize) -> Box<dyn LocalPolicy> {
        Box::new(ScriptedLocal)
    }
    fn bootstrap(&mut self, _view: &ClusterView) -> Vec<Action> {
        vec![Action::AddInstance {
            model: 0,
            class: InstanceClass::Mixed,
        }]
    }
    fn autoscale(&mut self, view: &ClusterView) -> Vec<Action> {
        self.ticks += 1;
        match self.ticks {
            1 => vec![Action::AddInstance {
                model: 0,
                class: InstanceClass::Mixed,
            }],
            2 => {
                // Both instances are still Loading (ready at t=15 and 16).
                let mut loading: Vec<InstanceId> = view
                    .instances
                    .iter()
                    .filter(|i| matches!(i.state, InstanceState::Loading { .. }))
                    .map(|i| i.id)
                    .collect();
                loading.sort_by_key(|id| id.0);
                assert_eq!(loading.len(), 2, "both instances should still be loading");
                vec![
                    Action::RemoveInstance { id: loading[1] },
                    Action::SetClass {
                        id: loading[0],
                        class: InstanceClass::Mixed,
                    },
                ]
            }
            _ => Vec::new(),
        }
    }
}

#[test]
fn removing_a_loading_instance_cancels_the_load_and_refunds_the_gpu() {
    // The pinned edge (sim/README.md): RemoveInstance on a Loading
    // instance drains it immediately (it is idle), the GPU is refunded at
    // the next barrier — before the load would have finished — and the
    // instance's stale Ready event no-ops. SetClass on Loading just
    // relabels. The survivor then serves the whole trace alone.
    let models = vec![ModelSpec::llama8b()];
    let mut rng = Rng::new(4);
    let trace = TraceBuilder::new()
        .stream(workload_a(5.0, 60, 0))
        .build(&mut rng);
    let mut cfg = SimConfig::new(4, models.clone());
    cfg.max_sim_time = 3600.0;
    cfg.record_gpu_trace = true;
    let mut p = ScriptedGlobal { ticks: 0 };
    let r = run_sim(cfg, trace, &mut p);
    assert_conserved(&r, "loading removal");
    assert!(!r.outcomes.is_empty());
    let peak = r.gpu_trace.iter().map(|&(_, u)| u).max().unwrap();
    assert_eq!(peak, 2, "the scripted add must have landed");
    // The refund lands before the cancelled load's ready time (t=16).
    let refunded_at = r
        .gpu_trace
        .iter()
        .find(|&&(t, u)| t > 1.0 && u == 1)
        .map(|&(t, _)| t)
        .expect("the loading instance's GPU must be refunded");
    assert!(
        refunded_at < 15.0,
        "refund at t={refunded_at} should precede the cancelled load's completion"
    );
    // And it never comes back: one instance serves the rest of the run.
    for &(t, used) in &r.gpu_trace {
        assert!(
            t <= refunded_at || used == 1,
            "t={t}: usage {used} after the removal"
        );
    }
}
