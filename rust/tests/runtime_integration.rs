//! Integration tests over the PJRT runtime and the real engine. These
//! require the AOT artifacts (`make artifacts`); they are skipped (pass
//! trivially with a notice) when artifacts are absent so `cargo test`
//! stays green on a fresh checkout.

use chiron::engine::{EngineRequest, LlmEngine};
use chiron::runtime::{Manifest, TinyLlmRuntime};
use chiron::server::ServingFrontend;

fn artifacts_dir() -> Option<String> {
    for cand in ["artifacts", "../artifacts"] {
        if Manifest::load(cand).is_ok() {
            return Some(cand.to_string());
        }
    }
    eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    None
}

#[test]
fn manifest_loads_with_expected_dims() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.dims.vocab, 256);
    assert_eq!(m.dims.max_seq, 128);
    assert!(!m.variants.is_empty());
    assert_eq!(m.variants[0].batch, 1);
}

#[test]
fn decode_is_deterministic_and_logits_finite() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = TinyLlmRuntime::load(&dir).unwrap();
    let cache = rt.empty_cache(1);
    let (l1, c1) = rt.decode(1, &[5], &[0], &cache).unwrap();
    let (l2, _) = rt.decode(1, &[5], &[0], &cache).unwrap();
    assert_eq!(l1, l2, "decode must be deterministic");
    assert!(l1.iter().all(|x| x.is_finite()));
    assert_eq!(l1.len(), rt.manifest.dims.vocab);
    assert_eq!(c1.len(), cache.len());
    // The cache must actually change (K/V written at position 0).
    assert_ne!(c1, cache);
}

#[test]
fn prefill_matches_decode_chain() {
    // The KV-cache correctness test across the FFI boundary: greedy chain
    // after a prefill must match a token-by-token decode from scratch.
    let Some(dir) = artifacts_dir() else { return };
    let rt = TinyLlmRuntime::load(&dir).unwrap();
    let prompt = [7i32, 11, 13, 17];
    let s = rt.manifest.dims.max_seq;

    // Path A: prefill then one decode.
    let mut tokens = vec![0i32; s];
    tokens[..4].copy_from_slice(&prompt);
    let (logits_a, cache_a) = rt.prefill(1, &tokens, &[4]).unwrap();
    let first_a = rt.argmax_row(&logits_a, 0);
    let (logits_a2, _) = rt.decode(1, &[first_a], &[4], &cache_a).unwrap();

    // Path B: decode token-by-token from an empty cache.
    let mut cache_b = rt.empty_cache(1);
    let mut logits_b = Vec::new();
    for (pos, &t) in prompt.iter().enumerate() {
        let (l, c) = rt.decode(1, &[t], &[pos as i32], &cache_b).unwrap();
        cache_b = c;
        logits_b = l;
    }
    let first_b = rt.argmax_row(&logits_b, 0);
    assert_eq!(first_a, first_b, "first generated token must agree");
    let (logits_b2, _) = rt.decode(1, &[first_b], &[4], &cache_b).unwrap();
    for (a, b) in logits_a2.iter().zip(&logits_b2) {
        assert!((a - b).abs() < 1e-3, "logits diverge: {a} vs {b}");
    }
}

#[test]
fn batch_rows_match_single_row_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = TinyLlmRuntime::load(&dir).unwrap();
    if !rt.batch_variants().contains(&4) {
        return;
    }
    // Batch of 4 identical rows must produce identical logits per row, and
    // match the single-row run.
    let cache4 = rt.empty_cache(4);
    let (l4, _) = rt.decode(4, &[9, 9, 9, 9], &[0; 4], &cache4).unwrap();
    let cache1 = rt.empty_cache(1);
    let (l1, _) = rt.decode(1, &[9], &[0], &cache1).unwrap();
    let v = rt.manifest.dims.vocab;
    for row in 0..4 {
        for i in 0..v {
            let a = l4[row * v + i];
            assert!((a - l1[i]).abs() < 1e-4, "row {row} logit {i}: {a} vs {}", l1[i]);
        }
    }
}

#[test]
fn engine_serves_batched_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = TinyLlmRuntime::load(&dir).unwrap();
    let mut engine = LlmEngine::new(rt, 4);
    for i in 0..6u64 {
        engine.submit(EngineRequest {
            id: i,
            prompt: vec![1 + i as i32, 2, 3],
            max_new_tokens: 5,
            arrival: None,
        });
    }
    let outcomes = engine.run_to_completion().unwrap();
    assert_eq!(outcomes.len(), 6);
    for o in &outcomes {
        assert_eq!(o.tokens.len(), 5);
        assert!(o.ttft >= 0.0 && o.total_latency >= o.ttft);
    }
    // Greedy decoding is deterministic: same prompt => same output.
    let rt2 = TinyLlmRuntime::load(&dir).unwrap();
    let mut e2 = LlmEngine::new(rt2, 4);
    e2.submit(EngineRequest {
        id: 0,
        prompt: vec![1, 2, 3],
        max_new_tokens: 5,
        arrival: None,
    });
    let again = e2.run_to_completion().unwrap();
    let orig = outcomes.iter().find(|o| o.id == 0).unwrap();
    assert_eq!(orig.tokens, again[0].tokens);
}

#[test]
fn engine_batch_size_affects_concurrency_not_results() {
    let Some(dir) = artifacts_dir() else { return };
    let gen = |max_batch: usize| {
        let rt = TinyLlmRuntime::load(&dir).unwrap();
        let mut e = LlmEngine::new(rt, max_batch);
        for i in 0..4u64 {
            e.submit(EngineRequest {
                id: i,
                prompt: vec![10 + i as i32, 20, 30],
                max_new_tokens: 6,
                arrival: None,
            });
        }
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|o| o.id);
        out.into_iter().map(|o| o.tokens).collect::<Vec<_>>()
    };
    assert_eq!(gen(1), gen(4), "batching must not change greedy outputs");
}

#[test]
fn frontend_threaded_serving() {
    let Some(dir) = artifacts_dir() else { return };
    let front = ServingFrontend::start(
        move || Ok(LlmEngine::new(TinyLlmRuntime::load(&dir)?, 4)),
        None,
    );
    for i in 0..5u64 {
        front
            .submit(EngineRequest {
                id: i,
                prompt: vec![2, 4, 6],
                max_new_tokens: 4,
                arrival: None,
            })
            .unwrap();
    }
    let outcomes = front.wait_for(5, std::time::Duration::from_secs(120));
    assert_eq!(outcomes.len(), 5);
    front.shutdown().unwrap();
}
