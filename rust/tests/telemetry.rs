//! Observability-plane integration tests, pinning the three telemetry
//! contracts:
//!
//!  1. **Zero effect when off (and on!)** — enabling full telemetry must
//!     not perturb simulation results: the FNV report digest is
//!     bit-identical with telemetry off and on, across the whole scenario
//!     catalog including the three fault scenarios.
//!  2. **Deterministic traces** — the merged event trace, decision audit,
//!     and exporter output are *byte-identical* at any `--shards` worker
//!     count.
//!  3. **Faithful audit** — every applied scale action in a fault run is
//!     attributable to a recorded autoscaler decision (`chiron explain`).

mod common;

use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::sim::{run_sim_source, SimConfig, SimReport};
use chiron::telemetry::export::{chrome_trace, explain, jsonl, prometheus_trace, slo_debug};
use chiron::telemetry::{LogHist, TelemetryConfig};
use chiron::workload::scenario::{by_name, catalog, ScenarioSpec};

use crate::common::digest_report;

fn run_spec(
    spec: &ScenarioSpec,
    seed: u64,
    shard_workers: usize,
    telemetry: TelemetryConfig,
) -> SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(spec.gpus, models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.faults = spec.faults.clone();
    cfg.telemetry = telemetry;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

#[test]
fn telemetry_on_vs_off_digests_identical_across_catalog() {
    // Acceptance: with telemetry disabled the whole-catalog digests are
    // bit-identical to a fully-instrumented run — recording observes the
    // simulation, never steers it. The catalog includes the three fault
    // scenarios (crash-midrush, spot-reclaim, straggler-tail), so crash /
    // retry / shed / reclamation emission paths are all covered.
    let mut saw_fault_scenario = 0;
    for spec in catalog() {
        let spec = common::test_scale(spec, 0.005);
        if !spec.faults.is_inert() {
            saw_fault_scenario += 1;
        }
        let off = run_spec(&spec, 11, 1, TelemetryConfig::off());
        let on = run_spec(&spec, 11, 1, TelemetryConfig::full());
        assert!(
            !off.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        assert_eq!(
            digest_report(&off),
            digest_report(&on),
            "{}: telemetry must not perturb the simulation",
            spec.name
        );
        assert!(off.trace.is_none(), "{}: off ⇒ no trace", spec.name);
        let trace = on.trace.as_ref().expect("full telemetry ⇒ trace");
        assert!(
            !trace.events.is_empty(),
            "{}: an instrumented run must record events",
            spec.name
        );
    }
    assert_eq!(
        saw_fault_scenario, 3,
        "the catalog should contain exactly the three fault scenarios"
    );
}

#[test]
fn traces_byte_identical_across_shard_workers() {
    // The determinism argument (telemetry/README.md): per-model shard
    // buffers concatenated in model order + a stable time sort make the
    // merged trace independent of worker scheduling. Pin it end-to-end:
    // both exporters' output is byte-equal at --shards 1 vs 4, on a fault
    // scenario (crash + retry + load events) and a multi-model one.
    for name in ["crash-midrush", "multi-tenant"] {
        let spec = by_name(name).expect("catalog scenario").scaled(0.02);
        let models = spec.model_specs().unwrap();
        let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
        let r1 = run_spec(&spec, 11, 1, TelemetryConfig::full());
        let r4 = run_spec(&spec, 11, 4, TelemetryConfig::full());
        assert_eq!(digest_report(&r1), digest_report(&r4), "{name}: digests");
        let (t1, t4) = (r1.trace.as_ref().unwrap(), r4.trace.as_ref().unwrap());
        assert_eq!(
            chrome_trace(t1, &names),
            chrome_trace(t4, &names),
            "{name}: chrome trace must be byte-identical at shards 1 vs 4"
        );
        assert_eq!(
            jsonl(t1),
            jsonl(t4),
            "{name}: jsonl trace must be byte-identical at shards 1 vs 4"
        );
        assert!(
            !t1.decisions.is_empty(),
            "{name}: an autoscaled run must record decisions"
        );
    }
}

#[test]
fn hist_sketch_matches_exact_quantiles_within_bin_error() {
    // The TTFT log-histogram assembled from per-shard sketches must agree
    // with exact quantiles computed from the retained outcomes, within the
    // sketch's guaranteed half-bin relative error.
    let spec = by_name("multi-tenant").expect("catalog scenario").scaled(0.02);
    let r = run_spec(&spec, 11, 4, TelemetryConfig::full());
    let trace = r.trace.as_ref().unwrap();
    let mut exact_ttft: Vec<f64> = r
        .outcomes
        .iter()
        .map(|o| o.first_token - o.arrival)
        .collect();
    exact_ttft.sort_by(|a, b| a.total_cmp(b));
    assert_eq!(trace.hists.ttft.count as usize, exact_ttft.len());
    for q in [0.5, 0.9, 0.99] {
        let est = trace.hists.ttft.quantile(q);
        let idx = ((q * exact_ttft.len() as f64) as usize).min(exact_ttft.len() - 1);
        let exact = exact_ttft[idx].max(1e-9);
        let rel = (est - exact).abs() / exact;
        assert!(
            rel <= LogHist::relative_error() + 0.02,
            "q={q}: sketch {est} vs exact {exact} (rel {rel})"
        );
    }
    // Merging per-shard sketches is order-independent: the same run at
    // shards 1 yields the identical histogram.
    let r1 = run_spec(&spec, 11, 1, TelemetryConfig::full());
    assert_eq!(r1.trace.as_ref().unwrap().hists.ttft, trace.hists.ttft);
    assert_eq!(r1.trace.as_ref().unwrap().hists.itl, trace.hists.itl);
}

#[test]
fn explain_attributes_every_scale_action_in_crash_midrush() {
    // Acceptance: `chiron explain` on a crash-midrush Chiron trace
    // attributes EVERY applied scale action to a recorded decision carrying
    // its backpressure inputs — in both exporter formats.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let models = spec.model_specs().unwrap();
    let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
    let r = run_spec(&spec, 11, 1, TelemetryConfig::full());
    let trace = r.trace.as_ref().unwrap();
    for text in [chrome_trace(trace, &names), jsonl(trace)] {
        let report = explain(&text).expect("explain must parse its own exporters");
        assert!(
            !report.contains("UNATTRIBUTED"),
            "every scale action must trace back to a decision:\n{report}"
        );
        let attr = report
            .lines()
            .find(|l| l.starts_with("attribution: "))
            .expect("explain must report attribution");
        let frac = attr
            .strip_prefix("attribution: ")
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        let (matched, total) = frac.split_once('/').expect("M/N fraction");
        assert_eq!(matched, total, "attribution must be complete: {attr}");
        assert!(
            total.parse::<usize>().unwrap() > 0,
            "a crash-midrush run must scale at least once: {attr}"
        );
        // The audit carries the IBP backpressure input for interactive adds.
        assert!(
            report.contains("ibp") || report.contains("bbp"),
            "decision groups must expose backpressure inputs:\n{report}"
        );
    }
}

#[test]
fn latency_decomposition_partitions_end_to_end_latency_bit_exactly() {
    // The SLO-forensics invariant (telemetry/README.md): for every
    // completed request, the phase breakdown — queue wait, load-delay
    // exposure, preemption stall, crash-retry rework, prefill, decode —
    // sums *bit-exactly* to completion − arrival. Pinned across all three
    // fault scenarios so crash/retry, reclamation, and straggler accrual
    // paths are all exercised, and with telemetry fully off to prove the
    // decomposition is always-on, not trace-gated.
    let mut checked = 0usize;
    let mut missed = 0usize;
    for name in ["crash-midrush", "spot-reclaim", "straggler-tail"] {
        let spec = by_name(name).expect("catalog scenario").scaled(0.02);
        let r = run_spec(&spec, 11, 4, TelemetryConfig::off());
        assert!(!r.outcomes.is_empty(), "{name}: scenario must complete work");
        for o in &r.outcomes {
            assert_eq!(
                o.phases.sum().to_bits(),
                o.latency().to_bits(),
                "{name}: phases of request {:?} must partition its latency \
                 ({:?} vs {})",
                o.id,
                o.phases,
                o.latency()
            );
            // Attribution is total: a dominant cause exists iff the SLO
            // was missed — never for met requests, always for missed ones.
            assert_eq!(
                o.miss_cause().is_some(),
                !o.slo_met(),
                "{name}: miss-cause must be attributed iff the SLO was missed"
            );
            checked += 1;
            missed += !o.slo_met() as usize;
        }
    }
    assert!(checked > 100, "fault catalog must complete real work");
    assert!(missed > 0, "fault scenarios must produce SLO misses to classify");
}

#[test]
fn windowed_series_byte_identical_across_shard_workers_in_all_exporters() {
    // Tentpole layer 3: the windowed backpressure/attainment series is
    // recorded single-threaded at tick barriers, so it is independent of
    // the shard worker count — and every exporter (Chrome trace, JSONL,
    // Prometheus exposition) must serialize it byte-identically at
    // --shards 1 vs 4. Windows tile [0, end) contiguously.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let models = spec.model_specs().unwrap();
    let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
    let r1 = run_spec(&spec, 11, 1, TelemetryConfig::full());
    let r4 = run_spec(&spec, 11, 4, TelemetryConfig::full());
    let (t1, t4) = (r1.trace.as_ref().unwrap(), r4.trace.as_ref().unwrap());
    assert!(
        !t1.windows.is_empty(),
        "full telemetry must record the windowed series"
    );
    assert_eq!(t1.windows, t4.windows, "window samples at shards 1 vs 4");
    assert_eq!(t1.misses, t4.misses, "miss records at shards 1 vs 4");
    assert_eq!(t1.windows[0].t0, 0.0, "first window starts at t=0");
    for w in t1.windows.windows(2) {
        assert_eq!(
            w[0].t1.to_bits(),
            w[1].t0.to_bits(),
            "windows must tile time contiguously"
        );
    }
    let last = t1.windows.last().unwrap();
    assert_eq!(
        last.t1.to_bits(),
        r1.end_time.to_bits(),
        "the final (partial) window is sealed at the run's end time"
    );
    assert_eq!(
        chrome_trace(t1, &names),
        chrome_trace(t4, &names),
        "chrome trace byte-identical with windows + misses"
    );
    assert_eq!(jsonl(t1), jsonl(t4), "jsonl byte-identical");
    let p1 = prometheus_trace(t1);
    assert_eq!(p1, prometheus_trace(t4), "prometheus exposition byte-identical");
    assert!(
        p1.contains("chiron_window_ibp") && p1.contains("chiron_slo_miss_total"),
        "prometheus exposition must carry the windowed series and blame counters"
    );
    // Cross-check: window completion counts sum to the terminal report.
    let windowed: u64 = t1.windows.iter().map(|w| w.completions).sum();
    assert_eq!(windowed as usize, r1.outcomes.len(), "windows cover every completion");
}

#[test]
fn slo_debug_attributes_every_miss_in_crash_midrush() {
    // Acceptance: `chiron slo-debug` on a crash-midrush Chiron trace
    // attributes a dominant cause to 100% of SLO-missed requests — no
    // UNATTRIBUTED rows — in both exporter formats, and names the worst
    // window for drilldown.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let models = spec.model_specs().unwrap();
    let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
    let r = run_spec(&spec, 11, 1, TelemetryConfig::full());
    let trace = r.trace.as_ref().unwrap();
    assert!(
        !trace.misses.is_empty(),
        "a crash run at this scale must miss some SLOs"
    );
    for text in [chrome_trace(trace, &names), jsonl(trace)] {
        let report = slo_debug(&text).expect("slo-debug must parse its own exporters");
        assert!(
            !report.contains("UNATTRIBUTED"),
            "every miss must carry a dominant cause:\n{report}"
        );
        let attr = report
            .lines()
            .find(|l| l.starts_with("attribution: "))
            .expect("slo-debug must report attribution");
        let frac = attr
            .strip_prefix("attribution: ")
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        let (matched, total) = frac.split_once('/').expect("M/N fraction");
        assert_eq!(matched, total, "attribution must be complete: {attr}");
        assert_eq!(
            total.parse::<usize>().unwrap(),
            trace.misses.len(),
            "slo-debug must see every recorded miss"
        );
        assert!(
            report.contains("worst window ["),
            "slo-debug must name the worst window for drilldown:\n{report}"
        );
    }
}

#[test]
fn timeline_reports_interactive_queue_and_cumulative_failures() {
    // Satellite: TimelinePoint now carries queued_interactive plus
    // cumulative failed/shed. On a shedding fault run the last sample must
    // agree with the report's terminal counters.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let r = run_spec(&spec, 11, 1, TelemetryConfig::off());
    assert!(!r.timeline.is_empty(), "timeline sampling defaults on");
    let last = r.timeline.last().unwrap();
    assert!(
        last.failed <= r.failed && last.shed <= r.shed,
        "cumulative counters never exceed the terminal report"
    );
    let monotone = r
        .timeline
        .windows(2)
        .all(|w| w[0].failed <= w[1].failed && w[0].shed <= w[1].shed);
    assert!(monotone, "failed/shed are cumulative, hence monotone");
}
