//! Observability-plane integration tests, pinning the three telemetry
//! contracts:
//!
//!  1. **Zero effect when off (and on!)** — enabling full telemetry must
//!     not perturb simulation results: the FNV report digest is
//!     bit-identical with telemetry off and on, across the whole scenario
//!     catalog including the three fault scenarios.
//!  2. **Deterministic traces** — the merged event trace, decision audit,
//!     and exporter output are *byte-identical* at any `--shards` worker
//!     count.
//!  3. **Faithful audit** — every applied scale action in a fault run is
//!     attributable to a recorded autoscaler decision (`chiron explain`).

mod common;

use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::sim::{run_sim_source, SimConfig, SimReport};
use chiron::telemetry::export::{chrome_trace, explain, jsonl};
use chiron::telemetry::{LogHist, TelemetryConfig};
use chiron::workload::scenario::{by_name, catalog, ScenarioSpec};

use crate::common::digest_report;

fn run_spec(
    spec: &ScenarioSpec,
    seed: u64,
    shard_workers: usize,
    telemetry: TelemetryConfig,
) -> SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(spec.gpus, models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.faults = spec.faults.clone();
    cfg.telemetry = telemetry;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

#[test]
fn telemetry_on_vs_off_digests_identical_across_catalog() {
    // Acceptance: with telemetry disabled the whole-catalog digests are
    // bit-identical to a fully-instrumented run — recording observes the
    // simulation, never steers it. The catalog includes the three fault
    // scenarios (crash-midrush, spot-reclaim, straggler-tail), so crash /
    // retry / shed / reclamation emission paths are all covered.
    let mut saw_fault_scenario = 0;
    for spec in catalog() {
        let spec = common::test_scale(spec, 0.005);
        if !spec.faults.is_inert() {
            saw_fault_scenario += 1;
        }
        let off = run_spec(&spec, 11, 1, TelemetryConfig::off());
        let on = run_spec(&spec, 11, 1, TelemetryConfig::full());
        assert!(
            !off.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        assert_eq!(
            digest_report(&off),
            digest_report(&on),
            "{}: telemetry must not perturb the simulation",
            spec.name
        );
        assert!(off.trace.is_none(), "{}: off ⇒ no trace", spec.name);
        let trace = on.trace.as_ref().expect("full telemetry ⇒ trace");
        assert!(
            !trace.events.is_empty(),
            "{}: an instrumented run must record events",
            spec.name
        );
    }
    assert_eq!(
        saw_fault_scenario, 3,
        "the catalog should contain exactly the three fault scenarios"
    );
}

#[test]
fn traces_byte_identical_across_shard_workers() {
    // The determinism argument (telemetry/README.md): per-model shard
    // buffers concatenated in model order + a stable time sort make the
    // merged trace independent of worker scheduling. Pin it end-to-end:
    // both exporters' output is byte-equal at --shards 1 vs 4, on a fault
    // scenario (crash + retry + load events) and a multi-model one.
    for name in ["crash-midrush", "multi-tenant"] {
        let spec = by_name(name).expect("catalog scenario").scaled(0.02);
        let models = spec.model_specs().unwrap();
        let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
        let r1 = run_spec(&spec, 11, 1, TelemetryConfig::full());
        let r4 = run_spec(&spec, 11, 4, TelemetryConfig::full());
        assert_eq!(digest_report(&r1), digest_report(&r4), "{name}: digests");
        let (t1, t4) = (r1.trace.as_ref().unwrap(), r4.trace.as_ref().unwrap());
        assert_eq!(
            chrome_trace(t1, &names),
            chrome_trace(t4, &names),
            "{name}: chrome trace must be byte-identical at shards 1 vs 4"
        );
        assert_eq!(
            jsonl(t1),
            jsonl(t4),
            "{name}: jsonl trace must be byte-identical at shards 1 vs 4"
        );
        assert!(
            !t1.decisions.is_empty(),
            "{name}: an autoscaled run must record decisions"
        );
    }
}

#[test]
fn hist_sketch_matches_exact_quantiles_within_bin_error() {
    // The TTFT log-histogram assembled from per-shard sketches must agree
    // with exact quantiles computed from the retained outcomes, within the
    // sketch's guaranteed half-bin relative error.
    let spec = by_name("multi-tenant").expect("catalog scenario").scaled(0.02);
    let r = run_spec(&spec, 11, 4, TelemetryConfig::full());
    let trace = r.trace.as_ref().unwrap();
    let mut exact_ttft: Vec<f64> = r
        .outcomes
        .iter()
        .map(|o| o.first_token - o.arrival)
        .collect();
    exact_ttft.sort_by(|a, b| a.total_cmp(b));
    assert_eq!(trace.hists.ttft.count as usize, exact_ttft.len());
    for q in [0.5, 0.9, 0.99] {
        let est = trace.hists.ttft.quantile(q);
        let idx = ((q * exact_ttft.len() as f64) as usize).min(exact_ttft.len() - 1);
        let exact = exact_ttft[idx].max(1e-9);
        let rel = (est - exact).abs() / exact;
        assert!(
            rel <= LogHist::relative_error() + 0.02,
            "q={q}: sketch {est} vs exact {exact} (rel {rel})"
        );
    }
    // Merging per-shard sketches is order-independent: the same run at
    // shards 1 yields the identical histogram.
    let r1 = run_spec(&spec, 11, 1, TelemetryConfig::full());
    assert_eq!(r1.trace.as_ref().unwrap().hists.ttft, trace.hists.ttft);
    assert_eq!(r1.trace.as_ref().unwrap().hists.itl, trace.hists.itl);
}

#[test]
fn explain_attributes_every_scale_action_in_crash_midrush() {
    // Acceptance: `chiron explain` on a crash-midrush Chiron trace
    // attributes EVERY applied scale action to a recorded decision carrying
    // its backpressure inputs — in both exporter formats.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let models = spec.model_specs().unwrap();
    let names: Vec<String> = models.iter().map(|m| m.name.clone()).collect();
    let r = run_spec(&spec, 11, 1, TelemetryConfig::full());
    let trace = r.trace.as_ref().unwrap();
    for text in [chrome_trace(trace, &names), jsonl(trace)] {
        let report = explain(&text).expect("explain must parse its own exporters");
        assert!(
            !report.contains("UNATTRIBUTED"),
            "every scale action must trace back to a decision:\n{report}"
        );
        let attr = report
            .lines()
            .find(|l| l.starts_with("attribution: "))
            .expect("explain must report attribution");
        let frac = attr
            .strip_prefix("attribution: ")
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        let (matched, total) = frac.split_once('/').expect("M/N fraction");
        assert_eq!(matched, total, "attribution must be complete: {attr}");
        assert!(
            total.parse::<usize>().unwrap() > 0,
            "a crash-midrush run must scale at least once: {attr}"
        );
        // The audit carries the IBP backpressure input for interactive adds.
        assert!(
            report.contains("ibp") || report.contains("bbp"),
            "decision groups must expose backpressure inputs:\n{report}"
        );
    }
}

#[test]
fn timeline_reports_interactive_queue_and_cumulative_failures() {
    // Satellite: TimelinePoint now carries queued_interactive plus
    // cumulative failed/shed. On a shedding fault run the last sample must
    // agree with the report's terminal counters.
    let spec = by_name("crash-midrush")
        .expect("catalog scenario")
        .scaled(0.02);
    let r = run_spec(&spec, 11, 1, TelemetryConfig::off());
    assert!(!r.timeline.is_empty(), "timeline sampling defaults on");
    let last = r.timeline.last().unwrap();
    assert!(
        last.failed <= r.failed && last.shed <= r.shed,
        "cumulative counters never exceed the terminal report"
    );
    let monotone = r
        .timeline
        .windows(2)
        .all(|w| w[0].failed <= w[1].failed && w[0].shed <= w[1].shed);
    assert!(monotone, "failed/shed are cumulative, hence monotone");
}
