//! Macro-stepping (fused decode) acceptance tests.
//!
//! The fusion tentpole collapses quiescent event-loop iterations: when an
//! instance's batch is pure decode with nothing to admit, the shard runs
//! its next k steps as a closed loop and emits one `StepDone` instead of
//! k. These tests pin the contracts that make that safe:
//!
//!  1. **Digest invariance** — for every catalog scenario (including the
//!     three fault scenarios), fused and stepwise runs are FNV-digest
//!     equal at shard worker counts 1 and 4, and the event-count identity
//!     `events_processed(fused) + steps_fused == events_processed(stepwise)`
//!     holds exactly (every fused step saved exactly one queue round-trip,
//!     and nothing else changed).
//!  2. **Telemetry auto-drop** — with the event sink enabled a fused run
//!     silently falls back to stepwise (`steps_fused == 0`) so per-step
//!     trace events stay byte-identical, and the simulation digest is
//!     still unchanged.
//!  3. **Phase decomposition stays bit-exact** — fused decode accrual
//!     feeds the same `PhaseBreakdown` ops, so every outcome's partition
//!     still sums to `completion − arrival` with zero error.
//!  4. **Checkpoint/resume** — a fused run killed mid-flight and resumed
//!     digests identically to an uninterrupted fused run, including the
//!     restored `steps_fused`/`events_processed` counters.
//!  5. **The week-scale hot path actually fuses** — `week-diurnal-100m`
//!     at test scale reports `steps_fused > 0`.

mod common;

use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::sim::checkpoint::{CheckpointConfig, CheckpointMeta};
use chiron::sim::{resume_sim_source, run_sim_source, SimConfig, SimReport};
use chiron::workload::scenario::{by_name, catalog, ScenarioSpec};

use crate::common::{digest_report, test_scale};

fn run_spec(
    spec: &ScenarioSpec,
    seed: u64,
    shard_workers: usize,
    fuse: bool,
    telemetry: bool,
) -> SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(spec.gpus, models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.faults = spec.faults.clone();
    cfg.fuse_steps = fuse;
    if telemetry {
        cfg.telemetry = chiron::telemetry::TelemetryConfig::full();
    }
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

#[test]
fn whole_catalog_digest_identical_fused_vs_stepwise() {
    let mut fused_total = 0u64;
    for spec in catalog() {
        let spec = test_scale(spec, 0.005);
        let stepwise = run_spec(&spec, 11, 1, false, false);
        assert!(
            !stepwise.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        assert_eq!(
            stepwise.steps_fused, 0,
            "{}: fusion off must fuse nothing",
            spec.name
        );
        let want = digest_report(&stepwise);
        for workers in [1usize, 4] {
            let fused = run_spec(&spec, 11, workers, true, false);
            assert_eq!(
                want,
                digest_report(&fused),
                "{}: fused/shards={workers} must be byte-identical to stepwise",
                spec.name
            );
            // Every fused step saved exactly one StepDone push+pop and
            // changed nothing else, so the event accounting closes exactly.
            assert_eq!(
                fused.events_processed + fused.steps_fused,
                stepwise.events_processed,
                "{}: shards={workers}: fused event savings must equal steps_fused",
                spec.name
            );
            fused_total += fused.steps_fused;
        }
    }
    assert!(
        fused_total > 0,
        "at least one catalog scenario must exercise the fused path"
    );
}

#[test]
fn telemetry_sink_auto_drops_to_stepwise() {
    let spec = by_name("flash-crowd").unwrap().scaled(0.05);
    let stepwise = run_spec(&spec, 7, 1, false, false);
    let traced = run_spec(&spec, 7, 1, true, true);
    assert_eq!(
        traced.steps_fused, 0,
        "an enabled event sink must force per-step events"
    );
    assert_eq!(
        digest_report(&stepwise),
        digest_report(&traced),
        "telemetry fallback must not perturb the simulation"
    );
    // Without the sink the same scenario does fuse — the fallback is the
    // sink's doing, not an accident of the workload.
    let fused = run_spec(&spec, 7, 1, true, false);
    assert!(
        fused.steps_fused > 0,
        "flash-crowd must fuse once telemetry is off"
    );
    assert_eq!(digest_report(&stepwise), digest_report(&fused));
}

#[test]
fn phase_breakdown_sums_bit_exactly_under_fusion() {
    // Fused decode accrues through the identical `finish_step` sequence,
    // so the ulp-corrected partition (queue + load + preempt + retry +
    // prefill + decode) still equals completion − arrival bit-for-bit.
    for name in ["paper-wa", "crash-midrush", "week-diurnal-100m"] {
        let spec = test_scale(by_name(name).unwrap(), 0.02);
        let fused = run_spec(&spec, 5, 1, true, false);
        assert!(!fused.outcomes.is_empty(), "{name}: must complete work");
        for o in &fused.outcomes {
            let latency = o.completion - o.arrival;
            assert_eq!(
                o.phases.sum().to_bits(),
                latency.to_bits(),
                "{name}: request {} phases must sum to its latency exactly",
                o.id.0
            );
        }
    }
}

fn meta_for(spec: &ScenarioSpec, seed: u64, scale: f64) -> CheckpointMeta {
    CheckpointMeta {
        scenario: spec.name.clone(),
        seed,
        scale,
        policy: "chiron".into(),
        gpus: spec.gpus,
    }
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("chiron-test-{}-{tag}.ckpt", std::process::id()))
}

#[test]
fn checkpoint_resume_bit_identical_with_fusion_on() {
    // crash-midrush is the hardest state to round-trip (fault RNG
    // mid-stream, retries, pending retirements); with fusion on the
    // barrier can only land where the horizon already handed back, so the
    // checkpoint cut is byte-stable.
    let spec = by_name("crash-midrush").unwrap().scaled(0.05);
    let models = spec.model_specs().unwrap();
    let seed = 11u64;
    let path = ckpt_path("fused-resume");
    let ck = CheckpointConfig {
        path: path.clone(),
        every: 60.0,
        meta: meta_for(&spec, seed, 0.05),
    };
    let mk_cfg = |max_time: f64, ck: Option<CheckpointConfig>| {
        let mut cfg = SimConfig::new(spec.gpus, models.clone());
        cfg.max_sim_time = max_time;
        cfg.shard_workers = 4;
        cfg.faults = spec.faults.clone();
        cfg.checkpoint = ck;
        cfg.fuse_steps = true;
        cfg
    };

    let mut p = make_policy(&PolicyKind::Chiron, &models);
    let full = run_sim_source(
        mk_cfg(spec.max_time, None),
        Box::new(spec.source(seed)),
        p.as_mut(),
    );
    assert!(!full.outcomes.is_empty(), "reference run must complete work");

    let mut p = make_policy(&PolicyKind::Chiron, &models);
    let _killed = run_sim_source(
        mk_cfg(400.0, Some(ck.clone())),
        Box::new(spec.source(seed)),
        p.as_mut(),
    );
    let bytes = std::fs::read(&path).expect("killed run must leave a checkpoint");

    let mut p = make_policy(&PolicyKind::Chiron, &models);
    let resumed = resume_sim_source(
        mk_cfg(spec.max_time, Some(ck)),
        Box::new(spec.source(seed)),
        p.as_mut(),
        &bytes,
    )
    .expect("resume must succeed");
    assert_eq!(
        digest_report(&full),
        digest_report(&resumed),
        "fused interrupted+resumed must be bit-identical to uninterrupted"
    );
    // The counters are part of shard state (checkpoint v3): the resumed
    // run's totals must equal the uninterrupted run's, not restart at 0.
    assert_eq!(full.steps_fused, resumed.steps_fused);
    assert_eq!(full.events_processed, resumed.events_processed);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn week_scenario_fuses_at_test_scale() {
    // The point of the tentpole: the week-scale hot path's quiescent
    // decode stretches collapse. At test scale (~2k requests, 4h cap)
    // arrivals are minutes apart and steps are ~tens of ms, so the bulk
    // of all engine steps must fuse.
    let spec = test_scale(by_name("week-diurnal-100m").unwrap(), 1.0);
    let fused = run_spec(&spec, 1, 1, true, false);
    assert!(
        fused.steps_fused > 0,
        "week-diurnal-100m at test scale must exercise the fused path"
    );
    let stepwise = run_spec(&spec, 1, 1, false, false);
    assert_eq!(digest_report(&fused), digest_report(&stepwise));
    assert!(
        fused.events_processed < stepwise.events_processed,
        "fusion must reduce event-queue traffic (fused {} vs stepwise {})",
        fused.events_processed,
        stepwise.events_processed
    );
}
