//! Event-core, sketch-metrics, and checkpoint/resume acceptance tests.
//!
//! The calendar-queue tentpole replaces the per-shard `BinaryHeap` with a
//! hierarchical timing wheel; these tests pin the three contracts that make
//! that (and the 100M-request scale features riding on it) safe:
//!
//!  1. **Bit-identical event cores** — for every catalog scenario, the
//!     calendar queue and the binary heap produce FNV-digest-equal reports,
//!     at shard worker counts 1 and 4.
//!  2. **Checkpoint/resume is invisible** — a run that is killed mid-flight
//!     and resumed from its last checkpoint digests identically to an
//!     uninterrupted run, and a checkpoint refuses to resume under
//!     different run parameters.
//!  3. **Sketch metrics are bounded-error** — with `sketch_metrics` on, the
//!     simulation itself is unperturbed (outcome digests equal) and the
//!     log-histogram quantiles land within the sketch's documented relative
//!     error of the exact percentiles.

mod common;

use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::metrics::Summary;
use chiron::sim::checkpoint::{CheckpointConfig, CheckpointMeta};
use chiron::sim::{resume_sim_source, run_sim_source, EventCore, SimConfig, SimReport};
use chiron::telemetry::LogHist;
use chiron::workload::scenario::{by_name, catalog, ScenarioSpec};

use crate::common::{digest_report, test_scale};

fn run_spec(
    spec: &ScenarioSpec,
    seed: u64,
    core: EventCore,
    shard_workers: usize,
    sketch: bool,
) -> SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(spec.gpus, models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.faults = spec.faults.clone();
    cfg.event_core = core;
    cfg.sketch_metrics = sketch;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

#[test]
fn whole_catalog_digest_identical_calendar_vs_heap() {
    // Acceptance: the calendar queue preserves the exact (t, pri, seq)
    // total order, so for every catalog scenario the two cores are
    // byte-identical — sequentially and through the worker pool.
    for spec in catalog() {
        let spec = test_scale(spec, 0.005);
        let heap = run_spec(&spec, 11, EventCore::Heap, 1, false);
        assert!(
            !heap.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        let want = digest_report(&heap);
        for (core, workers) in [
            (EventCore::Calendar, 1usize),
            (EventCore::Heap, 4),
            (EventCore::Calendar, 4),
        ] {
            let got = run_spec(&spec, 11, core, workers, false);
            assert_eq!(
                want,
                digest_report(&got),
                "{}: heap/shards=1 vs {}/shards={workers} must be byte-identical",
                spec.name,
                core.as_str()
            );
        }
    }
}

/// Build the checkpoint identity block the CLI would construct for a
/// `scenario run --checkpoint` invocation of `spec`.
fn meta_for(spec: &ScenarioSpec, seed: u64, scale: f64) -> CheckpointMeta {
    CheckpointMeta {
        scenario: spec.name.clone(),
        seed,
        scale,
        policy: "chiron".into(),
        gpus: spec.gpus,
    }
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("chiron-test-{}-{tag}.ckpt", std::process::id()))
}

#[test]
fn checkpoint_kill_resume_digest_equals_uninterrupted() {
    // crash-midrush: scheduled crashes, MTBF churn, and flaky loads make
    // this the hardest state to round-trip (fault RNG mid-stream, retry
    // counters, pending retirements). Kill the run mid-rush by capping
    // max_sim_time, then resume from the last checkpoint with the full
    // horizon — the final report must digest-equal an uninterrupted run.
    let spec = by_name("crash-midrush").unwrap().scaled(0.05);
    let models = spec.model_specs().unwrap();
    let seed = 11u64;
    for workers in [1usize, 4] {
        let path = ckpt_path(&format!("resume-w{workers}"));
        // 60 s cadence: the first checkpoint lands between the scheduled
        // crashes (60/75/90 s), while evicted work is still being retried.
        let ck = CheckpointConfig {
            path: path.clone(),
            every: 60.0,
            meta: meta_for(&spec, seed, 0.05),
        };
        let mk_cfg = |max_time: f64, ck: Option<CheckpointConfig>| {
            let mut cfg = SimConfig::new(spec.gpus, models.clone());
            cfg.max_sim_time = max_time;
            cfg.shard_workers = workers;
            cfg.faults = spec.faults.clone();
            cfg.checkpoint = ck;
            cfg
        };

        // Uninterrupted reference.
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let full = run_sim_source(
            mk_cfg(spec.max_time, None),
            Box::new(spec.source(seed)),
            p.as_mut(),
        );
        assert!(!full.outcomes.is_empty(), "reference run must complete work");

        // "Killed" run: checkpoints every 120 sim-seconds, dies at t=400
        // (after the three scheduled crashes at 60/75/90 s).
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let _killed = run_sim_source(
            mk_cfg(400.0, Some(ck.clone())),
            Box::new(spec.source(seed)),
            p.as_mut(),
        );
        let bytes = std::fs::read(&path).expect("killed run must leave a checkpoint");

        // Resume with the full horizon.
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let resumed = resume_sim_source(
            mk_cfg(spec.max_time, Some(ck.clone())),
            Box::new(spec.source(seed)),
            p.as_mut(),
            &bytes,
        )
        .expect("resume must succeed");
        assert_eq!(
            digest_report(&full),
            digest_report(&resumed),
            "shards={workers}: interrupted+resumed must be bit-identical to uninterrupted"
        );

        // A checkpoint refuses to resume under different run parameters.
        let mut wrong = ck.clone();
        wrong.meta.seed = seed + 1;
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let err = resume_sim_source(
            mk_cfg(spec.max_time, Some(wrong)),
            Box::new(spec.source(seed)),
            p.as_mut(),
            &bytes,
        );
        assert!(err.is_err(), "mismatched meta must be rejected");

        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn sketch_metrics_do_not_perturb_and_bound_quantile_error() {
    // Sketch mode only swaps the metric accumulators: the simulation's
    // outcome stream (and every counter the digest covers) is untouched,
    // and the log-histogram quantiles stay within the sketch's bin error
    // of the exact percentiles.
    let spec = by_name("flash-crowd").unwrap().scaled(0.05);
    let exact = run_spec(&spec, 7, EventCore::Calendar, 1, false);
    let sketch = run_spec(&spec, 7, EventCore::Calendar, 1, true);
    assert_eq!(
        digest_report(&exact),
        digest_report(&sketch),
        "sketch metrics must not perturb the simulation"
    );
    let es = Summary::of_report(&exact);
    let ss = Summary::of_report(&sketch);
    assert_eq!(es.count, ss.count);
    assert_eq!(
        es.slo_attainment, ss.slo_attainment,
        "SLO attainment is counter-based and stays exact in sketch mode"
    );
    // Bin-mid quantiles are within one half-bin of the true value; allow a
    // second half-bin for the nearest-rank vs interpolated-rank difference.
    let bound = 2.0 * LogHist::relative_error() + 0.02;
    for (name, e, s) in [
        ("ttft_p50", es.ttft_p50, ss.ttft_p50),
        ("ttft_p99", es.ttft_p99, ss.ttft_p99),
        ("itl_p99", es.itl_p99, ss.itl_p99),
    ] {
        assert!(
            e > 0.0 && s > 0.0,
            "{name}: quantiles must be populated (exact {e}, sketch {s})"
        );
        let rel = (s - e).abs() / e;
        assert!(
            rel <= bound,
            "{name}: sketch {s} vs exact {e} — relative error {rel:.4} > bound {bound:.4}"
        );
    }
}

#[test]
fn week_scenario_is_exactly_100m_requests() {
    // The scale target's composition is load-bearing for the benches and
    // docs: 72M diurnal chat + 21M steady API + 7 nightly 1M dumps.
    let spec = by_name("week-diurnal-100m").unwrap();
    assert_eq!(spec.total_requests(), Some(100_000_000));
    assert_eq!(spec.streams.len(), 9);
    assert_eq!(spec.max_time, 8.0 * 24.0 * 3600.0);
}
