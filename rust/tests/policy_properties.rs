//! Property-based invariant tests over the coordinator and simulator,
//! using the in-tree seeded property harness (proptest is unavailable
//! offline). Each property runs across randomized workloads/configs.

use chiron::coordinator::groups::build_groups;
use chiron::coordinator::waiting::WaitingTimeEstimator;
use chiron::coordinator::{BootstrapSpec, Chiron, ChironConfig, LocalAutoscaler, LocalConfig};
use chiron::core::{InstanceClass, InstanceId, ModelSpec, RequestClass};
use chiron::sim::policy::{InstanceState, InstanceView};
use chiron::sim::{run_sim, SimConfig};
use chiron::util::check::{gen, property};
use chiron::util::rng::Rng;
use chiron::workload::trace::{workload_a, workload_b_batch};
use chiron::workload::TraceBuilder;

fn small_cases() {
    // Keep whole-sim properties cheap.
    std::env::set_var("CHIRON_PROP_CASES", "12");
}

#[test]
fn sim_conserves_requests_and_tokens() {
    small_cases();
    property("request conservation", |rng| {
        let n_inter = gen::int_in(rng, 20, 200);
        let n_batch = gen::int_in(rng, 0, 200);
        let rate = gen::log_uniform(rng, 2.0, 40.0);
        let models = vec![ModelSpec::llama8b()];
        let mut trng = rng.fork();
        let trace = TraceBuilder::new()
            .stream(workload_a(rate, n_inter, 0))
            .stream(workload_b_batch(n_batch, 5.0, 0, 1200.0))
            .build(&mut trng);
        let expected_tokens: f64 = trace.requests.iter().map(|r| r.output_tokens as f64).sum();
        let mut cfg = ChironConfig::for_models(1);
        cfg.bootstrap[0] = BootstrapSpec {
            interactive: 1,
            mixed: 2,
            batch: 0,
        };
        let mut policy = Chiron::new(cfg, &models);
        let mut sim_cfg = SimConfig::new(20, models.clone());
        sim_cfg.max_sim_time = 3.0 * 3600.0;
        let report = run_sim(sim_cfg, trace, &mut policy);
        // Every request completes exactly once; token accounting matches.
        assert_eq!(report.unfinished, 0);
        assert_eq!(report.outcomes.len(), n_inter + n_batch);
        let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.id.0).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n_inter + n_batch, "duplicate completions");
        assert!(
            (report.total_tokens - expected_tokens).abs() < 1e-6 * expected_tokens.max(1.0),
            "tokens {} vs expected {}",
            report.total_tokens,
            expected_tokens
        );
        // Causality: first token after arrival, completion after first.
        for o in &report.outcomes {
            assert!(o.first_token >= o.arrival - 1e-9);
            assert!(o.completion >= o.first_token - 1e-9);
        }
    });
}

#[test]
fn gpu_budget_is_invariant_under_any_load() {
    small_cases();
    property("gpu budget", |rng| {
        let gpus = gen::int_in(rng, 2, 16) as u32;
        let models = vec![ModelSpec::llama8b()];
        let mut trng = rng.fork();
        let trace = TraceBuilder::new()
            .stream(workload_a(gen::log_uniform(rng, 5.0, 100.0), 150, 0))
            .stream(workload_b_batch(gen::int_in(rng, 0, 2000), 1.0, 0, 300.0))
            .build(&mut trng);
        let mut cfg = ChironConfig::for_models(1);
        cfg.bootstrap[0] = BootstrapSpec {
            interactive: 1,
            mixed: 1,
            batch: 0,
        };
        let mut policy = Chiron::new(cfg, &models);
        let mut sim_cfg = SimConfig::new(gpus, models.clone());
        sim_cfg.max_sim_time = 1800.0;
        sim_cfg.timeline_every = 1;
        let report = run_sim(sim_cfg, trace, &mut policy);
        for p in &report.timeline {
            assert!(p.gpus_used <= gpus, "t={} used {}", p.t, p.gpus_used);
        }
    });
}

#[test]
fn local_autoscaler_never_exceeds_bounds_and_converges() {
    property("algorithm 1 bounds", |rng| {
        let slo = gen::log_uniform(rng, 0.05, 2.0);
        let c = slo / gen::log_uniform(rng, 20.0, 2000.0); // plant: itl = c*b
        let cfg = LocalConfig::default();
        let mut la = LocalAutoscaler::new(cfg);
        let mut mb = gen::int_in(rng, 1, 64) as u32;
        let mut steps = 0u64;
        for _ in 0..300 {
            steps += 1;
            let itl = c * mb as f64;
            let thr = mb as f64 / itl.max(1e-9);
            let v = InstanceView {
                id: InstanceId(1),
                class: InstanceClass::Mixed,
                model: 0,
                state: InstanceState::Running,
                running: mb,
                running_interactive: 0,
                waiting: 2,
                max_batch: mb,
                kv_tokens: 0,
                kv_capacity: u64::MAX / 2,
                last_step_time: itl,
                last_decode_time: itl,
                throughput_tokens: thr,
                min_itl_slo: slo,
                steps,
            };
            if let Some(new) = la.on_step(&v) {
                assert!(new >= cfg.min_batch && new <= cfg.max_batch);
                mb = new;
            }
        }
        // Converged ITL must end at or below ~1.6x the SLO (halving bound).
        let final_itl = c * mb as f64;
        assert!(
            final_itl <= slo * 1.6,
            "final itl {final_itl} vs slo {slo} (mb {mb})"
        );
    });
}

#[test]
fn request_groups_partition_and_cover() {
    property("group partition", |rng| {
        let n = gen::int_in(rng, 1, 500);
        let base = rng.range_f64(0.0, 1e6);
        let deadlines: Vec<f64> = (0..n)
            .map(|_| base + rng.range_f64(0.0, 20_000.0))
            .collect();
        let stride = gen::int_in(rng, 1, 64);
        let g = build_groups(&deadlines, stride, rng.range_f64(10.0, 5000.0), 8);
        assert!(!g.is_empty());
        assert_eq!(g.iter().map(|x| x.count).sum::<usize>(), n * stride);
        // Earliest deadlines must be honest lower bounds per group.
        for gr in &g {
            assert!(gr.earliest_deadline <= gr.centroid + 1e-6);
        }
        // Groups sorted by deadline.
        assert!(g.windows(2).all(|w| w[0].centroid <= w[1].centroid));
    });
}

#[test]
fn waiting_estimator_is_monotone() {
    property("estimator monotonicity", |rng| {
        let mut est = WaitingTimeEstimator::new(gen::log_uniform(rng, 100.0, 10_000.0));
        for _ in 0..gen::int_in(rng, 0, 100) {
            est.observe_completion(gen::int_in(rng, 1, 2000) as u32);
        }
        let q1 = gen::log_uniform(rng, 1.0, 1e5);
        let q2 = q1 * rng.range_f64(1.0, 10.0);
        let i1 = gen::log_uniform(rng, 1.0, 50.0);
        let i2 = i1 * rng.range_f64(1.0, 8.0);
        // More queue => more wait; more instances => less wait.
        assert!(est.estimate_wait(q2, i1) >= est.estimate_wait(q1, i1) - 1e-12);
        assert!(est.estimate_wait(q1, i2) <= est.estimate_wait(q1, i1) + 1e-12);
        assert!(est.estimate_wait(q1, i1).is_finite());
    });
}

#[test]
fn interactive_requests_never_starve_behind_batch() {
    small_cases();
    property("interactive no-starvation", |rng| {
        let models = vec![ModelSpec::llama8b()];
        let mut trng = rng.fork();
        // Batch flood first, interactive arriving after.
        let trace = TraceBuilder::new()
            .stream(workload_b_batch(gen::int_in(rng, 500, 3000), 0.0, 0, 7200.0))
            .stream(workload_a(10.0, 100, 0))
            .build(&mut trng);
        let mut cfg = ChironConfig::for_models(1);
        cfg.bootstrap[0] = BootstrapSpec {
            interactive: 1,
            mixed: 2,
            batch: 0,
        };
        let mut policy = Chiron::new(cfg, &models);
        let mut sim_cfg = SimConfig::new(16, models.clone());
        sim_cfg.max_sim_time = 3.0 * 3600.0;
        let report = run_sim(sim_cfg, trace, &mut policy);
        // Interactive p99 TTFT stays bounded even under a batch flood
        // (preemptible mixed instances: paper §3).
        let mut worst: f64 = 0.0;
        for o in report
            .outcomes
            .iter()
            .filter(|o| o.class == RequestClass::Interactive)
        {
            worst = worst.max(o.ttft());
        }
        assert!(worst < 60.0, "interactive starved: worst ttft {worst}s");
    });
}

#[test]
fn deterministic_across_identical_runs() {
    small_cases();
    property("determinism", |rng| {
        let seed = rng.next_u64();
        let run = || {
            let models = vec![ModelSpec::llama8b()];
            let mut trng = Rng::new(seed);
            let trace = TraceBuilder::new()
                .stream(workload_a(15.0, 120, 0))
                .build(&mut trng);
            let mut cfg = ChironConfig::for_models(1);
            cfg.bootstrap[0] = BootstrapSpec {
                interactive: 1,
                mixed: 1,
                batch: 0,
            };
            let mut policy = Chiron::new(cfg, &models);
            let mut sim_cfg = SimConfig::new(8, models.clone());
            sim_cfg.max_sim_time = 1800.0;
            let r = run_sim(sim_cfg, trace, &mut policy);
            (
                r.outcomes.len(),
                r.end_time.to_bits(),
                r.total_tokens.to_bits(),
                r.scale_ups,
            )
        };
        assert_eq!(run(), run());
    });
}

#[test]
fn static_names_agree_with_dynamic_names() {
    // `GlobalPolicy::static_name` is a borrow-only duplicate of `name()`
    // (it lets `SimReport::finish` skip an allocation); the two must never
    // drift apart, or reports would silently carry a stale label. Covers
    // every CLI-reachable policy plus the `+forecast` decorator (which
    // composes its name dynamically and must NOT claim a static one).
    use chiron::experiments::common::{make_policy, PolicyKind};
    let models = vec![ModelSpec::llama8b()];
    for name in PolicyKind::NAMES {
        let kind = PolicyKind::parse(name).expect("catalog name parses");
        let policy = make_policy(&kind, &models);
        match policy.static_name() {
            Some(s) => assert_eq!(s, policy.name(), "{name}: static_name drifted"),
            None => assert!(
                matches!(kind, PolicyKind::Forecast { .. }),
                "{name}: fixed-name policies should provide static_name"
            ),
        }
    }
}
