//! Scenario-engine integration tests: JSON round-trips, streaming-vs-
//! materialized equivalence (byte-identical request sequences at any
//! `--jobs`), lazy generation of the 1M-request batch-backlog scenario,
//! and simulator equivalence between `Trace` and streaming arrivals.

mod common;

use chiron::core::Request;
use chiron::experiments::common::{make_policy, seed_list, PolicyKind};
use chiron::sim::{run_sim, run_sim_source, SimConfig};
use chiron::util::json::Json;
use chiron::util::parallel::run_grid_jobs;
use chiron::workload::scenario::{by_name, catalog};
use chiron::workload::{ArrivalSource, Trace};

use crate::common::{digest_report, digest_requests};

fn drain(mut src: impl ArrivalSource) -> Vec<Request> {
    let mut out = Vec::new();
    while let Some(r) = src.next_request() {
        out.push(r);
    }
    out
}

#[test]
fn trace_json_roundtrip_is_identical() {
    // A scenario trace exercises both classes, custom SLOs, and two models.
    let spec = by_name("multi-tenant").unwrap().scaled(0.01);
    let trace = spec.trace(11);
    assert!(trace.len() > 100, "need a non-trivial trace");
    let text = trace.to_json().to_string();
    let back = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(trace.len(), back.len());
    assert_eq!(
        digest_requests(&trace.requests),
        digest_requests(&back.requests),
        "round-tripped requests must be identical in every field"
    );
}

#[test]
fn streaming_source_matches_materialized_trace_10k() {
    // The acceptance scenario: a >= 10k-request multi-stream workload whose
    // streaming source must yield a byte-identical sequence to the
    // materialized trace, independent of the worker count used to fan
    // seeds (the source itself is per-task state; the grid must not
    // perturb it).
    let spec = by_name("paper-wb").unwrap().scaled(1.0 / 3.0);
    assert!(spec.max_requests() >= 10_000);
    let seeds = seed_list(42, 4);

    let materialized: Vec<u64> = seeds
        .iter()
        .map(|&s| digest_requests(&spec.trace(s).requests))
        .collect();
    let streamed_j1 = run_grid_jobs(1, seeds.clone(), |_, s| {
        digest_requests(&drain(spec.source(s)))
    });
    let streamed_j4 = run_grid_jobs(4, seeds.clone(), |_, s| {
        digest_requests(&drain(spec.source(s)))
    });
    assert_eq!(streamed_j1, materialized, "streaming == materialized");
    assert_eq!(streamed_j1, streamed_j4, "identical at --jobs 1 vs --jobs 4");
    // Seeds must actually differ from each other.
    let mut uniq = materialized.clone();
    uniq.sort();
    uniq.dedup();
    assert_eq!(uniq.len(), seeds.len());
}

#[test]
fn batch_backlog_streams_one_million_requests_lazily() {
    // The appendix-A.2 scenario: 1M batch requests dumped at t=300s. The
    // source holds one lookahead request per stream (O(streams) memory, by
    // construction — no Vec of requests exists anywhere in this test); we
    // drain it with O(1) bookkeeping and verify the contract.
    let spec = by_name("batch-backlog").unwrap();
    let mut src = spec.source(1);
    assert_eq!(src.stream_count(), 2);
    assert_eq!(src.total_hint(), Some(1_002_000));
    let mut n = 0usize;
    let mut last = f64::NEG_INFINITY;
    let mut ids_seen_max = 0u64;
    while let Some(r) = src.next_request() {
        assert!(r.arrival >= last, "arrivals must be time-ordered");
        last = r.arrival;
        ids_seen_max = ids_seen_max.max(r.id.0);
        n += 1;
    }
    assert_eq!(n, 1_002_000);
    assert_eq!(ids_seen_max, 1_001_999, "ids are dense and unique");
}

#[test]
fn simulator_streaming_equals_materialized_arrivals() {
    // The cluster refactor must be behavior-preserving: feeding the same
    // requests through `run_sim` (materialized) and `run_sim_source`
    // (streaming) yields bit-identical reports.
    let spec = by_name("flash-crowd").unwrap().scaled(0.03);
    let models = spec.model_specs().unwrap();
    for seed in [3u64, 19] {
        let mk_cfg = || {
            let mut cfg = SimConfig::new(spec.gpus, models.clone());
            cfg.max_sim_time = spec.max_time;
            cfg
        };
        let mut p1 = make_policy(&PolicyKind::Chiron, &models);
        let materialized = run_sim(mk_cfg(), spec.trace(seed), p1.as_mut());
        let mut p2 = make_policy(&PolicyKind::Chiron, &models);
        let streamed = run_sim_source(mk_cfg(), Box::new(spec.source(seed)), p2.as_mut());
        assert_eq!(materialized.outcomes.len(), streamed.outcomes.len());
        assert_eq!(
            digest_report(&materialized),
            digest_report(&streamed),
            "seed {seed}: streaming arrivals must not change simulation results"
        );
    }
}

#[test]
fn every_catalog_scenario_simulates_when_scaled_down() {
    // Smoke: each catalog entry drives the simulator end-to-end at 0.5%
    // scale under Chiron and completes with sane accounting.
    for spec in catalog() {
        let spec = common::test_scale(spec, 0.005);
        let models = spec.model_specs().unwrap();
        let mut cfg = SimConfig::new(spec.gpus, models.clone());
        cfg.max_sim_time = spec.max_time;
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let report = run_sim_source(cfg, Box::new(spec.source(5)), p.as_mut());
        assert!(
            !report.outcomes.is_empty(),
            "{}: no requests completed",
            spec.name
        );
        assert!(
            report.total_requests >= report.outcomes.len(),
            "{}: accounting",
            spec.name
        );
        assert_eq!(
            report.total_requests - report.outcomes.len(),
            report.unfinished,
            "{}: unfinished accounting",
            spec.name
        );
    }
}
