//! Shared helpers for the integration tests (not a test target itself —
//! cargo only builds top-level files under `tests/` as test crates).

// Each test crate includes this module and uses a subset of it.
#![allow(dead_code)]

use chiron::core::{Request, RequestOutcome};
use chiron::sim::SimReport;
use chiron::workload::scenario::ScenarioSpec;

/// Catalog-loop scaling for whole-catalog integration tests: `base` for
/// ordinary entries, but the 100M-request `week-diurnal-100m` scale target
/// gets a much deeper cut (2e-5 → ~2k requests) plus a 4-simulated-hour cap
/// so the loops stay fast. The nightly dumps after the cap simply never
/// arrive and are accounted as unfinished.
pub fn test_scale(spec: ScenarioSpec, base: f64) -> ScenarioSpec {
    if spec.name == "week-diurnal-100m" {
        let mut s = spec.scaled(2e-5);
        s.max_time = 4.0 * 3600.0;
        s
    } else {
        spec.scaled(base)
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

#[inline]
fn eat(h: &mut u64, x: u64) {
    for b in x.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

fn eat_outcome(h: &mut u64, o: &RequestOutcome) {
    eat(h, o.id.0);
    eat(h, o.class as u64);
    eat(h, o.model as u64);
    eat(h, o.slo.ttft.to_bits());
    eat(h, o.slo.itl.to_bits());
    eat(h, o.arrival.to_bits());
    eat(h, o.first_token.to_bits());
    eat(h, o.completion.to_bits());
    eat(h, o.input_tokens as u64);
    eat(h, o.output_tokens as u64);
    eat(h, o.mean_itl.to_bits());
    eat(h, o.max_itl.to_bits());
    eat(h, o.preemptions as u64);
}

/// FNV-1a over every bit of a report that could diverge: outcome ids,
/// classes, all latency timestamps (as raw f64 bits), token counts,
/// preemptions, plus the aggregate counters.
pub fn digest_report(report: &SimReport) -> u64 {
    let mut h = FNV_OFFSET;
    for o in &report.outcomes {
        eat_outcome(&mut h, o);
    }
    eat(&mut h, report.outcomes.len() as u64);
    eat(&mut h, report.scale_ups);
    eat(&mut h, report.scale_downs);
    eat(&mut h, report.gpu_seconds.to_bits());
    eat(&mut h, report.end_time.to_bits());
    eat(&mut h, report.total_requests as u64);
    eat(&mut h, report.unfinished as u64);
    eat(&mut h, report.total_tokens.to_bits());
    eat(&mut h, report.failed as u64);
    eat(&mut h, report.shed as u64);
    eat(&mut h, report.retries);
    h
}

/// FNV-1a over every field of a request sequence (f64s as raw bits).
pub fn digest_requests<'a, I: IntoIterator<Item = &'a Request>>(reqs: I) -> u64 {
    let mut h = FNV_OFFSET;
    for r in reqs {
        eat(&mut h, r.id.0);
        eat(&mut h, r.class as u64);
        eat(&mut h, r.slo.ttft.to_bits());
        eat(&mut h, r.slo.itl.to_bits());
        eat(&mut h, r.arrival.to_bits());
        eat(&mut h, r.input_tokens as u64);
        eat(&mut h, r.output_tokens as u64);
        eat(&mut h, r.model as u64);
    }
    h
}
