//! End-to-end integration tests: full traces through the discrete-event
//! simulator under Chiron and the baselines.

use chiron::baselines::{Llumnix, StaticPolicy};
use chiron::coordinator::{BootstrapSpec, Chiron, ChironConfig};
use chiron::core::{ModelSpec, RequestClass};
use chiron::sim::{run_sim, SimConfig};
use chiron::util::rng::Rng;
use chiron::workload::trace::{workload_a, workload_b_batch};
use chiron::workload::TraceBuilder;

fn chiron_for(models: &[ModelSpec], inter: u32, mixed: u32) -> Chiron {
    let mut cfg = ChironConfig::for_models(models.len());
    for b in &mut cfg.bootstrap {
        *b = BootstrapSpec {
            interactive: inter,
            mixed,
            batch: 0,
        };
    }
    Chiron::new(cfg, models)
}

#[test]
fn chiron_serves_interactive_workload_within_slo() {
    let models = vec![ModelSpec::llama8b()];
    let mut rng = Rng::new(1);
    let trace = TraceBuilder::new()
        .stream(workload_a(30.0, 2_000, 0))
        .build(&mut rng);
    let cfg = SimConfig::new(50, models.clone());
    let mut policy = chiron_for(&models, 2, 4);
    let report = run_sim(cfg, trace, &mut policy);
    assert_eq!(report.unfinished, 0, "all requests must finish");
    assert!(
        report.slo_attainment() > 0.9,
        "SLO attainment {} too low",
        report.slo_attainment()
    );
    assert!(report.gpu_seconds > 0.0);
}

#[test]
fn chiron_completes_batch_queue_before_deadline() {
    let models = vec![ModelSpec::llama8b()];
    let mut rng = Rng::new(2);
    let trace = TraceBuilder::new()
        .stream(workload_a(10.0, 500, 0))
        .stream(workload_b_batch(2_000, 10.0, 0, 1800.0))
        .build(&mut rng);
    let mut cfg = SimConfig::new(50, models.clone());
    cfg.max_sim_time = 3600.0 * 4.0;
    let mut policy = chiron_for(&models, 1, 3);
    let report = run_sim(cfg, trace, &mut policy);
    assert_eq!(report.unfinished, 0, "batch queue must drain");
    let batch_slo = report.slo_attainment_class(RequestClass::Batch);
    assert!(batch_slo > 0.8, "batch SLO attainment {batch_slo}");
}

#[test]
fn chiron_beats_llumnix_on_batch_dominated_load() {
    // The paper's core efficiency claim, in shape: on a batch-dominated
    // workload (where SLO-aware queuing + large batch instances pay off),
    // Chiron consumes fewer GPU·hours at equal-or-better SLO attainment.
    let models = vec![ModelSpec::llama8b()];
    let mk_trace = |seed| {
        let mut rng = Rng::new(seed);
        TraceBuilder::new()
            .stream(workload_a(10.0, 400, 0))
            .stream(workload_b_batch(20_000, 5.0, 0, 2400.0))
            .build(&mut rng)
    };
    let mut cfg = SimConfig::new(50, models.clone());
    cfg.max_sim_time = 3600.0 * 4.0;

    let mut chiron = chiron_for(&models, 1, 3);
    let r_chiron = run_sim(cfg.clone(), mk_trace(3), &mut chiron);

    let mut llumnix = Llumnix::untuned(&models);
    let r_llumnix = run_sim(cfg, mk_trace(3), &mut llumnix);

    assert_eq!(r_chiron.unfinished, 0);
    assert!(
        r_chiron.gpu_seconds < r_llumnix.gpu_seconds,
        "chiron {} GPUs·s vs llumnix {} GPUs·s",
        r_chiron.gpu_seconds,
        r_llumnix.gpu_seconds
    );
    assert!(
        r_chiron.slo_attainment() >= r_llumnix.slo_attainment() - 0.02,
        "chiron slo {} vs llumnix {}",
        r_chiron.slo_attainment(),
        r_llumnix.slo_attainment()
    );
}

#[test]
fn static_policy_is_deterministic() {
    let models = vec![ModelSpec::llama8b()];
    let run = || {
        let mut rng = Rng::new(7);
        let trace = TraceBuilder::new()
            .stream(workload_a(10.0, 300, 0))
            .build(&mut rng);
        let cfg = SimConfig::new(8, models.clone());
        let mut p = StaticPolicy::new(vec![2], 32);
        run_sim(cfg, trace, &mut p)
    };
    let a = run();
    let b = run();
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    assert_eq!(a.end_time, b.end_time);
    assert_eq!(a.total_tokens, b.total_tokens);
    let ka: Vec<_> = a.outcomes.iter().map(|o| (o.id, o.completion.to_bits())).collect();
    let kb: Vec<_> = b.outcomes.iter().map(|o| (o.id, o.completion.to_bits())).collect();
    assert_eq!(ka, kb, "simulation must be bit-deterministic");
}

#[test]
fn two_model_mixed_configuration_runs() {
    let models = vec![ModelSpec::llama8b(), ModelSpec::llama70b()];
    let mut rng = Rng::new(9);
    let trace = TraceBuilder::new()
        .stream(workload_a(20.0, 600, 0))
        .stream(workload_a(4.0, 150, 1))
        .build(&mut rng);
    let mut cfg = SimConfig::new(50, models.clone());
    cfg.max_sim_time = 3600.0;
    let mut policy = chiron_for(&models, 1, 3);
    let report = run_sim(cfg, trace, &mut policy);
    assert_eq!(report.unfinished, 0);
    assert!(report.slo_attainment() > 0.8, "{}", report.slo_attainment());
}

#[test]
fn gpu_budget_never_exceeded() {
    let models = vec![ModelSpec::llama8b()];
    let mut rng = Rng::new(11);
    let trace = TraceBuilder::new()
        .stream(workload_a(200.0, 3_000, 0)) // heavy overload
        .stream(workload_b_batch(5_000, 0.0, 0, 600.0)) // urgent batch
        .build(&mut rng);
    let mut cfg = SimConfig::new(10, models.clone());
    cfg.max_sim_time = 1800.0;
    cfg.timeline_every = 1;
    let mut policy = chiron_for(&models, 1, 2);
    let report = run_sim(cfg, trace, &mut policy);
    for p in &report.timeline {
        assert!(p.gpus_used <= 10, "budget exceeded at t={}: {}", p.t, p.gpus_used);
    }
}
