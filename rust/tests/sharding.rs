//! Sharded-simulator equivalence and invariant tests.
//!
//! The epoch driver runs per-model event-loop shards between autoscaler
//! tick barriers; `--shards N` only chooses how many worker threads advance
//! them. These tests pin the two contracts that make that safe:
//!
//!  1. **Bit-identical results at any worker count** — the monolithic
//!     (sequential, `shard_workers = 1`) pass and the parallel
//!     (`shard_workers = 4`) pass produce FNV-digest-equal reports for
//!     every catalog scenario and for a 4-model workload where every shard
//!     genuinely runs concurrently.
//!  2. **Barrier-quantized GPU budget** — the cluster-level `gpus_used`
//!     only changes at tick barriers (mid-epoch retirements are credited,
//!     not applied, until the next barrier).

mod common;

use chiron::core::ModelSpec;
use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::sim::{run_sim_source, SimConfig};
use chiron::workload::scenario::{catalog, ScenarioSpec};
use chiron::workload::trace::{workload_a, workload_b_batch};

use crate::common::digest_report;

fn run_spec(spec: &ScenarioSpec, seed: u64, shard_workers: usize, record: bool) -> chiron::sim::SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(spec.gpus, models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.record_gpu_trace = record;
    cfg.faults = spec.faults.clone();
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

#[test]
fn whole_catalog_digest_identical_across_shard_workers() {
    // Acceptance: for every catalog scenario, runs through the persistent
    // worker pool (shard_workers ∈ {2, 4}) are byte-identical (FNV digest)
    // to the inline pass — the same engine advancing all shards
    // sequentially on the caller's thread (shard_workers = 1, no pool).
    // Equivalence to the *pre-refactor* single-heap loop is argued, not
    // digest-pinned, in sim/README.md: exact for single-model runs,
    // report-accumulation-order-different for multi-model ones.
    for spec in catalog() {
        let spec = common::test_scale(spec, 0.005);
        let inline = run_spec(&spec, 11, 1, false);
        assert!(
            !inline.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        for workers in [2usize, 4] {
            let pooled = run_spec(&spec, 11, workers, false);
            assert_eq!(
                digest_report(&inline),
                digest_report(&pooled),
                "{}: --shards 1 (inline) and --shards {workers} (pool) must be byte-identical",
                spec.name
            );
        }
    }
}

/// A 4-model scenario built from the trace recipes so all four shards hold
/// real concurrent work (interactive streams plus per-model batch dumps).
fn four_model_spec() -> (Vec<ModelSpec>, impl Fn(u64) -> chiron::workload::Trace) {
    let models = vec![
        ModelSpec::llama8b(),
        ModelSpec::llama8b(),
        ModelSpec::llama8b(),
        ModelSpec::llama70b(),
    ];
    let mk = |seed: u64| {
        let mut rng = chiron::util::rng::Rng::new(seed);
        let mut tb = chiron::workload::TraceBuilder::new();
        for m in 0..4 {
            let rate = if m == 3 { 3.0 } else { 12.0 };
            let n = if m == 3 { 60 } else { 250 };
            tb = tb
                .stream(workload_a(rate, n, m))
                .stream(workload_b_batch(400, 5.0 + m as f64, m, 1800.0));
        }
        tb.build(&mut rng)
    };
    (models, mk)
}

fn run_four_model(seed: u64, shard_workers: usize) -> chiron::sim::SimReport {
    let (models, mk) = four_model_spec();
    let mut cfg = SimConfig::new(60, models.clone());
    cfg.max_sim_time = 4.0 * 3600.0;
    cfg.shard_workers = shard_workers;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    chiron::sim::run_sim(cfg, mk(seed), p.as_mut())
}

#[test]
fn four_model_shards_are_bit_identical_and_deterministic() {
    for seed in [7u64, 23] {
        let d1 = digest_report(&run_four_model(seed, 1));
        let d2 = digest_report(&run_four_model(seed, 2));
        let d4 = digest_report(&run_four_model(seed, 4));
        let d4b = digest_report(&run_four_model(seed, 4));
        assert_eq!(d1, d2, "seed {seed}: shards 1 vs 2");
        assert_eq!(d1, d4, "seed {seed}: shards 1 vs 4");
        assert_eq!(d4, d4b, "seed {seed}: parallel rerun must be identical");
    }
    // Different seeds must actually change the digest (not vacuous).
    assert_ne!(
        digest_report(&run_four_model(7, 4)),
        digest_report(&run_four_model(23, 4))
    );
}

#[test]
fn baselines_are_bit_identical_across_shard_workers() {
    // The split-policy migration covers every baseline: run each through
    // the 4-model workload at both worker counts.
    let (models, mk) = four_model_spec();
    for kind in [
        PolicyKind::LlumnixUntuned,
        PolicyKind::LocalOnly,
        PolicyKind::GlobalOnly(64),
    ] {
        let run = |workers: usize| {
            let mut cfg = SimConfig::new(60, models.clone());
            cfg.max_sim_time = 4.0 * 3600.0;
            cfg.shard_workers = workers;
            let mut p = make_policy(&kind, &models);
            chiron::sim::run_sim(cfg, mk(5), p.as_mut())
        };
        assert_eq!(
            digest_report(&run(1)),
            digest_report(&run(4)),
            "{kind:?}: shards 1 vs 4"
        );
    }
}

#[test]
fn gpus_used_only_changes_at_tick_barriers() {
    // A workload with scale-up then drain-down so the trace records both
    // budget growth and releases. tick_interval = 1.0 keeps barrier times
    // exactly representable, so any mid-epoch change would show a
    // fractional timestamp.
    let models = vec![ModelSpec::llama8b()];
    let mut rng = chiron::util::rng::Rng::new(3);
    let trace = chiron::workload::TraceBuilder::new()
        .stream(workload_a(10.0, 300, 0))
        .stream(workload_b_batch(3_000, 5.0, 0, 900.0))
        .build(&mut rng);
    for workers in [1usize, 4] {
        let mut cfg = SimConfig::new(30, models.clone());
        cfg.max_sim_time = 2.0 * 3600.0;
        cfg.shard_workers = workers;
        cfg.record_gpu_trace = true;
        assert_eq!(cfg.tick_interval, 1.0);
        let mut p = make_policy(&PolicyKind::Chiron, &models);
        let report = chiron::sim::run_sim(cfg, trace.clone(), p.as_mut());
        assert!(
            report.gpu_trace.len() >= 4,
            "expected a non-trivial budget history, got {:?}",
            report.gpu_trace
        );
        let mut saw_release = false;
        let mut prev = 0u32;
        for &(t, used) in &report.gpu_trace {
            assert_eq!(
                t.fract(),
                0.0,
                "budget changed between barriers at t={t} (workers={workers})"
            );
            if used < prev {
                saw_release = true;
            }
            prev = used;
        }
        assert!(
            saw_release,
            "workload should have scaled down at least once (workers={workers})"
        );
    }
}
