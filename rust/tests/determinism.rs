//! Determinism regression tests: the simulator must be bit-reproducible
//! for a fixed (seed, config), and the parallel sweep runner must produce
//! byte-identical grids at any `--jobs` setting (results are slotted by
//! task index, never by completion order).

mod common;

use chiron::core::{ModelSpec, RequestClass};
use chiron::experiments::common::{make_policy, run_one, trace_wb, PolicyKind};
use chiron::sim::SimReport;
use chiron::util::parallel::run_grid_jobs;
use crate::common::digest_report as digest;

fn models() -> Vec<ModelSpec> {
    vec![ModelSpec::llama8b()]
}

fn run_kind(kind: &PolicyKind, seed: u64) -> SimReport {
    let models = models();
    let trace = trace_wb(&models, &[15.0], 300, &[1_200], 1800.0, 5.0, seed);
    let mut p = make_policy(kind, &models);
    run_one(&models, 50, trace, p.as_mut(), 4.0 * 3600.0)
}

#[test]
fn same_seed_same_config_is_bit_identical() {
    // Chiron exercises every event type: loads, ticks, evictions,
    // reclassification — the full event loop must replay identically.
    let a = run_kind(&PolicyKind::Chiron, 42);
    let b = run_kind(&PolicyKind::Chiron, 42);
    assert!(a.total_requests > 0 && a.outcomes.len() > 0);
    assert_eq!(digest(&a), digest(&b), "rerun must be bit-identical");

    // And a different seed must actually change the digest (the digest is
    // not vacuously constant).
    let c = run_kind(&PolicyKind::Chiron, 43);
    assert_ne!(digest(&a), digest(&c), "digest must be seed-sensitive");
}

#[test]
fn grid_results_identical_across_jobs_1_and_n() {
    // The full four-policy comparison grid — the compare() workload — must
    // produce byte-identical reports whether run inline (--jobs 1) or
    // fanned out over the persistent worker pool at any width.
    let kinds = vec![
        PolicyKind::Chiron,
        PolicyKind::LlumnixUntuned,
        PolicyKind::LocalOnly,
        PolicyKind::GlobalOnly(64),
    ];
    let grid = |jobs: usize| -> Vec<u64> {
        let tasks: Vec<&PolicyKind> = kinds.iter().collect();
        run_grid_jobs(jobs, tasks, |_, kind| digest(&run_kind(kind, 7)))
    };
    let serial = grid(1);
    assert_eq!(serial.len(), kinds.len());
    for jobs in [2usize, 4] {
        assert_eq!(
            serial,
            grid(jobs),
            "--jobs 1 (inline) and --jobs {jobs} (pool) grids must be byte-identical, in order"
        );
    }
    // Policies genuinely differ, so the grid isn't a constant vector.
    assert!(
        serial.windows(2).any(|w| w[0] != w[1]),
        "distinct policies should yield distinct digests"
    );
}

#[test]
fn interactive_and_batch_classes_both_complete_deterministically() {
    let r = run_kind(&PolicyKind::Chiron, 5);
    let inter = r
        .outcomes
        .iter()
        .filter(|o| o.class == RequestClass::Interactive)
        .count();
    let batch = r
        .outcomes
        .iter()
        .filter(|o| o.class == RequestClass::Batch)
        .count();
    assert!(inter > 0, "interactive requests must complete");
    assert!(batch > 0, "batch requests must complete");
    let r2 = run_kind(&PolicyKind::Chiron, 5);
    assert_eq!(digest(&r), digest(&r2));
}
