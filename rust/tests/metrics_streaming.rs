//! Streaming-metrics equivalence tests.
//!
//! The simulator folds every completion into `SimReport::stats`
//! (`metrics::SummaryAccum`) as it happens; the per-request outcome buffer
//! is optional (`SimConfig::keep_outcomes`). These tests pin the contract
//! that makes that safe to rely on:
//!
//!  1. **Accumulator == buffered summary, bit for bit** — on a run that
//!     kept its outcomes, `report.stats.summary()` equals
//!     `Summary::of(&report.outcomes)` field-exactly (same f64 bits),
//!     overall and per class, including multi-model runs where shard
//!     accumulators merge in model order.
//!  2. **`keep_outcomes = false` changes memory, not results** — outcomes
//!     come back empty while the `Summary`, `PolicyRow`, and every
//!     aggregate report field match the buffered run exactly.

use chiron::core::{ModelSpec, RequestClass};
use chiron::experiments::common::{make_policy, trace_wb, PolicyKind};
use chiron::metrics::{PolicyRow, Summary};
use chiron::sim::{run_sim, SimConfig, SimReport};
use chiron::workload::trace::{workload_a, workload_b_batch};

fn assert_summary_bits_eq(ctx: &str, a: &Summary, b: &Summary) {
    assert_eq!(a.count, b.count, "{ctx}: count");
    for (name, x, y) in [
        ("slo_attainment", a.slo_attainment, b.slo_attainment),
        ("ttft_p50", a.ttft_p50, b.ttft_p50),
        ("ttft_p99", a.ttft_p99, b.ttft_p99),
        ("itl_mean", a.itl_mean, b.itl_mean),
        ("itl_p99", a.itl_p99, b.itl_p99),
        (
            "preemptions_per_request",
            a.preemptions_per_request,
            b.preemptions_per_request,
        ),
        ("mean_output_tokens", a.mean_output_tokens, b.mean_output_tokens),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}: {x} != {y}");
    }
}

/// A ~10k-request multi-class run: 3k interactive at 30 req/s plus a 7k
/// batch dump at t = 5 s.
fn run_10k(keep_outcomes: bool, shard_workers: usize) -> SimReport {
    let models = vec![ModelSpec::llama8b()];
    let trace = trace_wb(&models, &[30.0], 3_000, &[7_000], 1800.0, 5.0, 97);
    let mut cfg = SimConfig::new(50, models.clone());
    cfg.max_sim_time = 4.0 * 3600.0;
    cfg.keep_outcomes = keep_outcomes;
    cfg.shard_workers = shard_workers;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim(cfg, trace, p.as_mut())
}

/// A 4-model run so the accumulator's model-order merge is exercised.
fn run_multi_model(keep_outcomes: bool, shard_workers: usize) -> SimReport {
    let models = vec![
        ModelSpec::llama8b(),
        ModelSpec::llama8b(),
        ModelSpec::llama8b(),
        ModelSpec::llama70b(),
    ];
    let mut rng = chiron::util::rng::Rng::new(13);
    let mut tb = chiron::workload::TraceBuilder::new();
    for m in 0..4 {
        tb = tb
            .stream(workload_a(10.0, 200, m))
            .stream(workload_b_batch(300, 5.0 + m as f64, m, 1800.0));
    }
    let trace = tb.build(&mut rng);
    let mut cfg = SimConfig::new(60, models.clone());
    cfg.max_sim_time = 4.0 * 3600.0;
    cfg.keep_outcomes = keep_outcomes;
    cfg.shard_workers = shard_workers;
    let mut p = make_policy(&PolicyKind::Chiron, &models);
    run_sim(cfg, trace, p.as_mut())
}

#[test]
fn accumulator_matches_buffered_summary_on_10k_multi_class_run() {
    let report = run_10k(true, 1);
    assert!(
        report.outcomes.len() > 9_000,
        "expected ~10k completions, got {}",
        report.outcomes.len()
    );
    let classes = report
        .outcomes
        .iter()
        .map(|o| o.class)
        .collect::<std::collections::HashSet<_>>();
    assert_eq!(classes.len(), 2, "run must complete both request classes");
    assert_summary_bits_eq(
        "overall",
        &Summary::of(&report.outcomes),
        &report.stats.summary(),
    );
    for class in [RequestClass::Interactive, RequestClass::Batch] {
        assert_summary_bits_eq(
            &format!("{class:?}"),
            &Summary::of_class(&report.outcomes, class),
            &report.stats.summary_class(class),
        );
    }
}

#[test]
fn accumulator_merge_order_matches_buffer_on_multi_model_run() {
    // Shard accumulators merge in model order; the outcome buffer
    // concatenates in model order — the two must stay bit-identical, with
    // the shards advanced inline or on the pool.
    for workers in [1usize, 2] {
        let report = run_multi_model(true, workers);
        assert!(!report.outcomes.is_empty());
        assert_summary_bits_eq(
            &format!("workers={workers}"),
            &Summary::of(&report.outcomes),
            &report.stats.summary(),
        );
    }
}

#[test]
fn streaming_mode_drops_outcomes_but_matches_buffered_results() {
    for workers in [1usize, 4] {
        let buffered = run_10k(true, workers);
        let streaming = run_10k(false, workers);
        assert!(
            streaming.outcomes.is_empty(),
            "keep_outcomes = false must not retain per-request records"
        );
        assert!(!buffered.outcomes.is_empty());

        // Summaries and rows are bit-identical.
        assert_summary_bits_eq(
            &format!("of_report workers={workers}"),
            &Summary::of_report(&buffered),
            &Summary::of_report(&streaming),
        );
        let (rb, rs) = (
            PolicyRow::from_report(&buffered),
            PolicyRow::from_report(&streaming),
        );
        assert_eq!(rb.line(), rs.line(), "PolicyRow must match exactly");
        assert_eq!(rb.to_json().to_string(), rs.to_json().to_string());

        // Every aggregate report field matches.
        assert_eq!(buffered.policy, streaming.policy);
        assert_eq!(buffered.scale_ups, streaming.scale_ups);
        assert_eq!(buffered.scale_downs, streaming.scale_downs);
        assert_eq!(
            buffered.gpu_seconds.to_bits(),
            streaming.gpu_seconds.to_bits()
        );
        assert_eq!(buffered.end_time.to_bits(), streaming.end_time.to_bits());
        assert_eq!(buffered.total_requests, streaming.total_requests);
        assert_eq!(buffered.unfinished, streaming.unfinished);
        assert_eq!(
            buffered.total_tokens.to_bits(),
            streaming.total_tokens.to_bits()
        );
        assert_eq!(buffered.stats.count(), streaming.stats.count());
        assert_eq!(buffered.stats.met(), streaming.stats.met());
        assert_eq!(
            buffered.outcomes.len(),
            streaming.stats.count(),
            "streaming accumulator must have folded every completion"
        );
    }
}

#[test]
fn streaming_multi_model_matches_buffered_on_pool() {
    let buffered = run_multi_model(true, 4);
    let streaming = run_multi_model(false, 4);
    assert!(streaming.outcomes.is_empty());
    assert_summary_bits_eq(
        "multi-model",
        &Summary::of(&buffered.outcomes),
        &streaming.stats.summary(),
    );
    assert_eq!(
        buffered.gpu_seconds.to_bits(),
        streaming.gpu_seconds.to_bits()
    );
}
