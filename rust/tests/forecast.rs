//! Forecast-plane integration tests.
//!
//! Three contracts:
//!  1. **Estimator convergence** — the online estimators recover the true
//!     rate of synthetic constant and phased Poisson streams.
//!  2. **Digest determinism** — `PredictiveScaler`-decorated policies are
//!     FNV-digest bit-identical at `shard_workers` 1 vs 4 and `--jobs`
//!     1 vs 4 across the scenario catalog, same as the reactive policies
//!     (tests/sharding.rs): the decorator reads only the merged barrier
//!     `ClusterView` and mutates state on the driver thread.
//!  3. **Budget safety** — pre-provisioning never pushes `gpus_used` past
//!     `gpus_total`, even on a cluster with almost no headroom.

mod common;

use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::forecast::{ForecasterKind, RateForecaster};
use chiron::sim::{run_sim_source, SimConfig, SimReport};
use chiron::util::parallel::run_grid_jobs;
use chiron::util::rng::Rng;
use chiron::workload::scenario::{catalog, by_name, ScenarioSpec};

use crate::common::digest_report;

fn predictive_chiron(lead: f64) -> PolicyKind {
    PolicyKind::Chiron.with_forecast(
        ForecasterKind::parse("holt-winters").unwrap(),
        lead,
    )
}

fn run_spec(
    spec: &ScenarioSpec,
    kind: &PolicyKind,
    seed: u64,
    shard_workers: usize,
    gpus: Option<u32>,
    record: bool,
) -> SimReport {
    let models = spec.model_specs().unwrap();
    let mut cfg = SimConfig::new(gpus.unwrap_or(spec.gpus), models.clone());
    cfg.max_sim_time = spec.max_time;
    cfg.shard_workers = shard_workers;
    cfg.record_gpu_trace = record;
    let mut p = make_policy(kind, &models);
    run_sim_source(cfg, Box::new(spec.source(seed)), p.as_mut())
}

/// Poisson counts per 1-second tick at `rate`, fed straight to a forecaster.
fn feed_poisson_ticks(f: &mut dyn RateForecaster, rate: f64, ticks: usize, rng: &mut Rng) {
    for _ in 0..ticks {
        let mut n = 0.0;
        let mut t = 0.0;
        loop {
            t += rng.exp(rate);
            if t > 1.0 {
                break;
            }
            n += 1.0;
        }
        f.observe(n, 1.0);
    }
}

#[test]
fn estimators_converge_on_constant_poisson() {
    let mut rng = Rng::new(5);
    for name in ForecasterKind::NAMES {
        let mut f = ForecasterKind::parse(name).unwrap().build();
        feed_poisson_ticks(f.as_mut(), 24.0, 900, &mut rng);
        // The window mean averages ~120 ticks (tight); EWMA/HW weight the
        // recent past, so per-tick Poisson noise leaves a wider band.
        let lvl_tol = if *name == "window" { 1.5 } else { 5.0 };
        let lvl = f.level().unwrap();
        assert!(
            (lvl - 24.0).abs() < lvl_tol,
            "{name}: level {lvl} should approach the true rate 24"
        );
        // Wider band for Holt–Winters: the trend term amplifies sampling
        // noise over the 45 s horizon (flat estimators forecast the level).
        let tol = if *name == "holt-winters" { 12.0 } else { 5.0 };
        let fut = f.forecast(45.0).unwrap();
        assert!(
            (fut - 24.0).abs() < tol,
            "{name}: constant-rate 45s forecast {fut} should stay near 24"
        );
    }
}

#[test]
fn estimators_track_phased_poisson_step() {
    // A phased stream: 6/s for 400 ticks, then 30/s. Every estimator must
    // re-converge after the step; Holt–Winters must overshoot ahead during
    // the transient (trend > 0), which is exactly what buys lead time.
    let mut rng = Rng::new(9);
    for name in ForecasterKind::NAMES {
        let mut f = ForecasterKind::parse(name).unwrap().build();
        feed_poisson_ticks(f.as_mut(), 6.0, 400, &mut rng);
        let before = f.level().unwrap();
        assert!((before - 6.0).abs() < 3.0, "{name}: pre-step level {before}");
        feed_poisson_ticks(f.as_mut(), 30.0, 400, &mut rng);
        let after = f.level().unwrap();
        assert!(
            (after - 30.0).abs() < 7.0,
            "{name}: post-step level {after} should approach 30"
        );
    }
}

#[test]
fn predictive_digest_identical_across_shard_workers_whole_catalog() {
    let kind = predictive_chiron(45.0);
    for spec in catalog() {
        let spec = common::test_scale(spec, 0.004);
        let mono = run_spec(&spec, &kind, 11, 1, None, false);
        let sharded = run_spec(&spec, &kind, 11, 4, None, false);
        assert!(
            !mono.outcomes.is_empty(),
            "{}: scenario must complete work",
            spec.name
        );
        assert_eq!(
            digest_report(&mono),
            digest_report(&sharded),
            "{}: chiron+hw must be byte-identical at shards 1 vs 4",
            spec.name
        );
    }
}

#[test]
fn predictive_baseline_digest_identical_across_shard_workers() {
    // The decorator must stay deterministic over a baseline too, and with
    // every estimator kind (not just Holt–Winters).
    let spec = by_name("spike-correlated").unwrap().scaled(0.02);
    for est in ForecasterKind::NAMES {
        let kind = PolicyKind::LlumnixUntuned
            .with_forecast(ForecasterKind::parse(est).unwrap(), 60.0);
        let a = run_spec(&spec, &kind, 7, 1, None, false);
        let b = run_spec(&spec, &kind, 7, 4, None, false);
        assert_eq!(
            digest_report(&a),
            digest_report(&b),
            "llumnix+{est}: shards 1 vs 4"
        );
    }
}

#[test]
fn predictive_digest_identical_across_jobs() {
    // (seed) grid fanned over 1 vs 4 workers: per-cell digests must match
    // slot for slot (the scaler is built per worker, so nothing shared).
    let spec = by_name("flash-crowd").unwrap().scaled(0.02);
    let kind = predictive_chiron(45.0);
    let digests = |jobs: usize| -> Vec<u64> {
        let seeds: Vec<u64> = vec![1, 2, 3, 4, 5, 6];
        run_grid_jobs(jobs, seeds, |_, seed| {
            digest_report(&run_spec(&spec, &kind, seed, 1, None, false))
        })
    };
    assert_eq!(digests(1), digests(4), "jobs 1 vs 4 must agree per seed");
}

#[test]
fn preprovisioning_never_exceeds_gpu_budget() {
    // Property over the whole correlated-spike run on a nearly full
    // cluster: the recorded cluster-level budget trace must never cross
    // gpus_total, at either worker count. (The scaler also self-limits —
    // unit-tested in forecast::scaler — this pins the end-to-end result.)
    let spec = by_name("spike-correlated").unwrap().scaled(0.05);
    for workers in [1usize, 4] {
        for gpus in [8u32, 16] {
            let report = run_spec(
                &spec,
                &predictive_chiron(45.0),
                3,
                workers,
                Some(gpus),
                true,
            );
            assert!(
                !report.gpu_trace.is_empty(),
                "expected budget history (workers={workers}, gpus={gpus})"
            );
            for &(t, used) in &report.gpu_trace {
                assert!(
                    used <= gpus,
                    "budget violated at t={t}: {used} > {gpus} (workers={workers})"
                );
            }
        }
    }
}

#[test]
fn predictive_run_reports_forecast_accuracy_and_reactive_does_not() {
    let spec = by_name("diurnal").unwrap().scaled(0.05);
    let predictive = run_spec(&spec, &predictive_chiron(45.0), 4, 1, None, false);
    assert!(
        !predictive.forecast.is_empty(),
        "predictive run must carry per-model forecast scores"
    );
    let s = &predictive.forecast[0];
    assert_eq!(s.model, 0);
    assert_eq!(s.estimator, "hw");
    assert!(s.n > 10, "matured pairs: {}", s.n);
    assert!(s.r2 <= 1.0 + 1e-9, "r2 {}", s.r2);
    assert!(s.mape >= 0.0, "mape {}", s.mape);
    assert!(
        predictive.policy.ends_with("+hw"),
        "policy name {}",
        predictive.policy
    );

    let reactive = run_spec(&spec, &PolicyKind::Chiron, 4, 1, None, false);
    assert!(reactive.forecast.is_empty(), "reactive runs carry no scores");
}

#[test]
fn policy_kind_parses_forecast_suffix() {
    for name in ["chiron+forecast", "llumnix+forecast"] {
        let kind = PolicyKind::parse(name).unwrap_or_else(|| panic!("{name} must parse"));
        match kind {
            PolicyKind::Forecast { lead_time, .. } => assert!(lead_time > 0.0),
            other => panic!("{name} parsed to {other:?}"),
        }
    }
    assert!(PolicyKind::parse("nope+forecast").is_none());
    // One decorator layer only: repeated suffixes must not stack scalers.
    assert!(PolicyKind::parse("chiron+forecast+forecast").is_none());
    assert!(PolicyKind::NAMES.contains(&"chiron+forecast"));
}
