//! Hot-path micro/meso benchmarks (custom harness; criterion unavailable
//! offline). Run with `cargo bench --bench hotpath [-- <filter>]`.
//! Quick mode: CHIRON_BENCH_QUICK=1.
//!
//! These are the §Perf L3 profiling targets: the simulator event loop,
//! router, waiting-time estimator, request grouping, and the local
//! autoscaler step.

use chiron::coordinator::groups::build_groups;
use chiron::coordinator::waiting::WaitingTimeEstimator;
use chiron::coordinator::{
    BootstrapSpec, Chiron, ChironConfig, ChironLocal, LocalAutoscaler, LocalConfig,
};
use chiron::core::{
    InstanceClass, InstanceId, ModelSpec, PhaseBreakdown, Request, RequestClass, RequestId,
    RequestOutcome, Slo, WaitKind,
};
use chiron::experiments::common::{make_policy, PolicyKind};
use chiron::forecast::{ForecasterKind, RateForecaster};
use chiron::sim::policy::{
    InstanceState, InstanceView, LocalPolicy, ModelView, QueuedReq,
};
use chiron::sim::{run_sim, run_sim_source, EventCore, SimConfig, SimInstance, WorkItem};
use chiron::metrics::{Summary, SummaryAccum};
use chiron::util::bench::{black_box, Bencher};
use chiron::util::parallel::{for_each_mut, run_grid_jobs};
use chiron::util::rng::Rng;
use chiron::workload::trace::{workload_a, workload_b_batch};
use chiron::workload::{ShareGptSampler, TraceBuilder};

fn instances(n: u32) -> Vec<InstanceView> {
    (0..n)
        .map(|i| InstanceView {
            id: InstanceId(i),
            class: if i % 3 == 0 {
                InstanceClass::Interactive
            } else if i % 3 == 1 {
                InstanceClass::Mixed
            } else {
                InstanceClass::Batch
            },
            model: 0,
            state: InstanceState::Running,
            running: (i * 7) % 64,
            running_interactive: (i * 3) % 32,
            waiting: i % 4,
            max_batch: 64,
            kv_tokens: (i as u64 * 1000) % 400_000,
            kv_capacity: 800_000,
            last_step_time: 0.03,
            last_decode_time: 0.03,
            throughput_tokens: 2000.0,
            min_itl_slo: 0.2,
            steps: 100 + i as u64,
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let models = vec![ModelSpec::llama8b()];

    // -- RNG + sampling -----------------------------------------------------
    {
        let mut rng = Rng::new(1);
        b.bench_units("rng.u64 x1000", Some(1000.0), || {
            let mut acc = 0u64;
            for _ in 0..1000 {
                acc ^= rng.next_u64();
            }
            black_box(acc);
        });
        let sampler = ShareGptSampler::new();
        b.bench_units("sharegpt.sample x1000", Some(1000.0), || {
            let mut acc = 0u32;
            for _ in 0..1000 {
                let (i, o) = sampler.sample(&mut rng);
                acc = acc.wrapping_add(i + o);
            }
            black_box(acc);
        });
    }

    // -- router (the per-model local half) ----------------------------------
    {
        let insts = instances(50);
        let mut local = ChironLocal::new(LocalConfig::default());
        let req = QueuedReq {
            id: RequestId(1),
            class: RequestClass::Interactive,
            model: 0,
            arrival: 0.0,
            ttft_deadline: 10.0,
            itl_slo: 0.2,
            input_tokens: 128,
        };
        b.bench_units("chiron.route interactive (50 inst)", Some(1.0), || {
            let view = ModelView {
                now: 0.0,
                model: 0,
                instances: &insts,
            };
            black_box(local.route(&req, &view));
        });
    }

    // -- local autoscaler -------------------------------------------------
    {
        let mut la = LocalAutoscaler::new(LocalConfig::default());
        let insts = instances(1);
        let mut step = 0u64;
        b.bench_units("local_autoscaler.on_step", Some(1.0), || {
            step += 1;
            let mut v = insts[0].clone();
            v.steps = step;
            black_box(la.on_step(&v));
        });
    }

    // -- waiting-time estimator + groups -----------------------------------
    {
        let mut est = WaitingTimeEstimator::new(5000.0);
        for i in 0..1000 {
            est.observe_completion(100 + (i % 400));
        }
        b.bench_units("estimator.estimate_wait", Some(1.0), || {
            black_box(est.estimate_wait(123_456.0, 7.0));
        });
        let deadlines: Vec<f64> = (0..2048).map(|i| 1000.0 + (i % 7) as f64 * 600.0).collect();
        b.bench_units("build_groups (2048 sample)", Some(2048.0), || {
            black_box(build_groups(&deadlines, 64, 300.0, 6));
        });
    }

    // -- instance view snapshot (the per-step policy input) -----------------
    {
        let mut inst = SimInstance::new(
            InstanceId(0),
            InstanceClass::Mixed,
            0,
            ModelSpec::llama8b().profile,
            64,
            0.0,
        );
        inst.state = InstanceState::Running;
        for i in 0..64u64 {
            inst.enqueue(WorkItem::fresh(Request {
                id: RequestId(i),
                class: if i % 4 == 0 {
                    RequestClass::Interactive
                } else {
                    RequestClass::Batch
                },
                slo: if i % 4 == 0 {
                    Slo::interactive_default()
                } else {
                    Slo::batch_default()
                },
                arrival: 0.0,
                input_tokens: 2,
                output_tokens: 10_000,
                model: 0,
            }));
        }
        let d = inst.begin_step(0.0).expect("work admitted");
        inst.finish_step(d, d);
        assert_eq!(inst.running_len(), 64);
        // §Perf target: O(1) and heap-free regardless of the running set
        // (the seed scanned all running requests twice per snapshot).
        b.bench_units("instance.view x1000 (64 running)", Some(1000.0), || {
            let mut steps = 0u64;
            for _ in 0..1000 {
                steps = steps.wrapping_add(black_box(inst.view()).steps);
            }
            black_box(steps);
        });
    }

    // -- streaming scenario source ------------------------------------------
    // Drain a ~10k-request multi-stream catalog scenario through the lazy
    // k-way merge (the trace-side hot path for scenario runs; memory stays
    // O(streams) regardless of request count).
    {
        use chiron::workload::scenario::by_name;
        use chiron::workload::ArrivalSource;
        let spec = by_name("paper-wb").expect("catalog scenario").scaled(1.0 / 3.0);
        let total = spec.max_requests() as f64;
        b.bench_units("scenario.stream_10k", Some(total), || {
            let mut src = spec.source(7);
            let mut n = 0usize;
            let mut acc = 0u64;
            while let Some(r) = src.next_request() {
                acc = acc.wrapping_add(r.id.0 ^ r.output_tokens as u64);
                n += 1;
            }
            black_box((n, acc));
        });
    }

    // -- end-to-end simulator throughput -----------------------------------
    {
        let mk = |n_inter: usize, n_batch: usize| {
            let mut rng = Rng::new(3);
            TraceBuilder::new()
                .stream(workload_a(30.0, n_inter, 0))
                .stream(workload_b_batch(n_batch, 5.0, 0, 1800.0))
                .build(&mut rng)
        };
        let trace = mk(2000, 4000);
        let total = trace.len() as f64;
        b.bench_units("sim.run chiron 6k requests", Some(total), || {
            let mut cfg = ChironConfig::for_models(1);
            cfg.bootstrap[0] = BootstrapSpec {
                interactive: 1,
                mixed: 2,
                batch: 0,
            };
            let mut policy = Chiron::new(cfg, &models);
            let mut sim_cfg = SimConfig::new(50, models.clone());
            sim_cfg.max_sim_time = 4.0 * 3600.0;
            sim_cfg.timeline_every = 0;
            let r = run_sim(sim_cfg, mk(2000, 4000), &mut policy);
            black_box(r.outcomes.len());
        });
        // The same workload through the predictive decorator: the delta vs
        // `sim.run` is the forecast plane's whole overhead (per-barrier
        // observation + estimator update + injected-action scan). The bench
        // gate prefers exact/word-boundary name matches, so "sim.run" pins
        // the bench above regardless of registration order.
        b.bench_units("sim.run_forecast chiron+hw 6k requests", Some(total), || {
            let kind = PolicyKind::Chiron.with_forecast(
                ForecasterKind::parse("holt-winters").expect("known estimator"),
                45.0,
            );
            let mut policy = make_policy(&kind, &models);
            let mut sim_cfg = SimConfig::new(50, models.clone());
            sim_cfg.max_sim_time = 4.0 * 3600.0;
            sim_cfg.timeline_every = 0;
            let r = run_sim(sim_cfg, mk(2000, 4000), policy.as_mut());
            black_box(r.outcomes.len());
        });
        // The same workload with full telemetry (events + decision audit +
        // counters + histograms): the delta vs `sim.run` is the whole
        // observability-plane overhead when tracing is ON. The OFF path is
        // pinned by the gate on `sim.run` itself — the sink's disabled
        // branch is an Option check the gate would catch regressing.
        b.bench_units("sim.run_traced chiron 6k requests", Some(total), || {
            let mut cfg = ChironConfig::for_models(1);
            cfg.bootstrap[0] = BootstrapSpec {
                interactive: 1,
                mixed: 2,
                batch: 0,
            };
            let mut policy = Chiron::new(cfg, &models);
            let mut sim_cfg = SimConfig::new(50, models.clone());
            sim_cfg.max_sim_time = 4.0 * 3600.0;
            sim_cfg.timeline_every = 0;
            sim_cfg.telemetry = chiron::telemetry::TelemetryConfig::full();
            let r = run_sim(sim_cfg, mk(2000, 4000), &mut policy);
            let events = r.trace.as_ref().map_or(0, |t| t.events.len());
            black_box((r.outcomes.len(), events));
        });
    }

    // -- calendar queue vs binary heap on the same workload ------------------
    // The event-core A/B: identical 6k workload through each core, identical
    // results (whole-catalog digest equality is pinned by
    // tests/event_core.rs), so the delta is pure queue mechanics. The CI
    // gate tracks the calendar entry (registered first — the gate's
    // word-boundary match takes the first "sim.calendar_vs_heap " hit); the
    // heap entry rides along so the trajectory records the A/B ratio.
    {
        let mk = |n_inter: usize, n_batch: usize| {
            let mut rng = Rng::new(3);
            TraceBuilder::new()
                .stream(workload_a(30.0, n_inter, 0))
                .stream(workload_b_batch(n_batch, 5.0, 0, 1800.0))
                .build(&mut rng)
        };
        let total = mk(2000, 4000).len() as f64;
        let run_core = |core: EventCore, trace: chiron::workload::Trace| {
            let mut cfg = ChironConfig::for_models(1);
            cfg.bootstrap[0] = BootstrapSpec {
                interactive: 1,
                mixed: 2,
                batch: 0,
            };
            let mut policy = Chiron::new(cfg, &models);
            let mut sim_cfg = SimConfig::new(50, models.clone());
            sim_cfg.max_sim_time = 4.0 * 3600.0;
            sim_cfg.timeline_every = 0;
            sim_cfg.event_core = core;
            let r = run_sim(sim_cfg, trace, &mut policy);
            black_box(r.outcomes.len());
        };
        b.bench_units("sim.calendar_vs_heap calendar 6k requests", Some(total), || {
            run_core(EventCore::Calendar, mk(2000, 4000))
        });
        b.bench_units("sim.calendar_vs_heap heap 6k requests", Some(total), || {
            run_core(EventCore::Heap, mk(2000, 4000))
        });
    }

    // -- fused macro-stepping vs stepwise on a quiescent-decode workload ----
    // The macro-stepping A/B: a sparse interactive stream (2 req/s) on a
    // single pinned instance (1 GPU — the autoscaler cannot add a second),
    // so between arrivals the batch is pure decode and nearly every engine
    // step is fusable. Results are bit-identical either way
    // (tests/macro_step.rs pins the whole catalog), so the delta is the
    // per-step event-queue round-trip fusion eliminates. The CI gate
    // tracks the fused entry (registered first); the stepwise entry rides
    // along so the trajectory records the ratio.
    {
        let mk_sparse = || {
            let mut rng = Rng::new(9);
            TraceBuilder::new()
                .stream(workload_a(2.0, 2000, 0))
                .build(&mut rng)
        };
        let total = mk_sparse().len() as f64;
        let run_fuse = |fuse: bool, trace: chiron::workload::Trace| {
            let mut cfg = ChironConfig::for_models(1);
            cfg.bootstrap[0] = BootstrapSpec {
                interactive: 0,
                mixed: 1,
                batch: 0,
            };
            let mut policy = Chiron::new(cfg, &models);
            let mut sim_cfg = SimConfig::new(1, models.clone());
            sim_cfg.max_sim_time = 4.0 * 3600.0;
            sim_cfg.timeline_every = 0;
            sim_cfg.fuse_steps = fuse;
            let r = run_sim(sim_cfg, trace, &mut policy);
            if fuse {
                assert!(r.steps_fused > 0, "sparse decode workload must fuse");
            } else {
                assert_eq!(r.steps_fused, 0);
            }
            black_box((r.outcomes.len(), r.steps_fused));
        };
        b.bench_units("sim.fused_vs_stepwise fused 2k sparse", Some(total), || {
            run_fuse(true, mk_sparse())
        });
        b.bench_units("sim.fused_vs_stepwise stepwise 2k sparse", Some(total), || {
            run_fuse(false, mk_sparse())
        });
    }

    // -- telemetry event recording ------------------------------------------
    // 1M enabled-sink pushes: the marginal per-event cost a traced run pays
    // at every emission site (enum construct + Vec push).
    {
        use chiron::telemetry::{EventKind, EventSink};
        b.bench_units("telemetry.record_1m", Some(1e6), || {
            let mut sink = EventSink::new(true);
            for i in 0..1_000_000u64 {
                sink.push(
                    i as f64 * 1e-3,
                    (i % 4) as usize,
                    EventKind::Arrival {
                        req: i,
                        class: if i % 3 == 0 {
                            RequestClass::Batch
                        } else {
                            RequestClass::Interactive
                        },
                    },
                );
            }
            black_box(sink.drain().len());
        });
    }

    // -- latency decomposition + miss-cause classification ------------------
    // 1M rounds of the SLO-forensics hot path: phase accrual (wait charges +
    // the ulp-exact close), dominant-cause classification, and the blame-
    // table fold. Bounds the always-on per-completion cost the forensics
    // plane adds on top of plain summarization.
    {
        use chiron::metrics::MissTable;
        b.bench_units("telemetry.decompose_1m", Some(1e6), || {
            let mut table = MissTable::default();
            let mut o = RequestOutcome {
                id: RequestId(0),
                class: RequestClass::Interactive,
                slo: Slo::interactive_default(),
                model: 0,
                arrival: 0.0,
                first_token: 1.0,
                completion: 30.0,
                input_tokens: 128,
                output_tokens: 100,
                mean_itl: 0.05,
                max_itl: 0.1,
                preemptions: 0,
                retries: 0,
                phases: PhaseBreakdown::default(),
            };
            for i in 0..1_000_000u64 {
                let wait = 0.5 + (i % 7) as f64 * 0.25;
                o.model = (i % 4) as usize;
                o.class = if i % 3 == 0 {
                    RequestClass::Batch
                } else {
                    RequestClass::Interactive
                };
                o.phases = PhaseBreakdown::default();
                o.phases.charge_wait(WaitKind::Queue, wait);
                o.phases.charge_wait(WaitKind::from_u8((i % 4) as u8), 0.3);
                o.phases.close(o.latency());
                table.push(&o);
            }
            black_box(table.total());
        });
    }

    // -- the fault plane under load -----------------------------------------
    // crash-midrush's FaultSpec (three scheduled crashes, MTBF churn, flaky
    // loads) through the streaming source at quarter scale. The delta vs
    // `sim.run` bounds the fault plane's whole overhead: crash eviction +
    // re-queue, retry accounting, and the per-event fault checks (fault-free
    // runs skip them entirely — inert `ModelFaults` short-circuits).
    {
        use chiron::workload::scenario::by_name;
        let spec = by_name("crash-midrush")
            .expect("catalog scenario")
            .scaled(0.25);
        let models_f = spec.model_specs().expect("known models");
        let total = spec.max_requests() as f64;
        b.bench_units("sim.run_faults crash-midrush 4.5k requests", Some(total), || {
            let mut cfg = SimConfig::new(spec.gpus, models_f.clone());
            cfg.max_sim_time = spec.max_time;
            cfg.timeline_every = 0;
            cfg.keep_outcomes = false;
            cfg.faults = spec.faults.clone();
            let mut policy = make_policy(&PolicyKind::Chiron, &models_f);
            let r = run_sim_source(cfg, Box::new(spec.source(3)), policy.as_mut());
            assert_eq!(r.unfinished, 0, "fault run must account every request");
            black_box(r.stats.count());
        });
    }

    // -- forecast estimator update (the per-barrier hot path) ---------------
    // One Holt–Winters observe + lead-time forecast per autoscaler tick per
    // model; must stay trivially cheap next to the event loop.
    {
        let mut hw = ForecasterKind::parse("holt-winters")
            .expect("known estimator")
            .build();
        let mut k = 0u64;
        b.bench_units("forecast.hw_update x1000", Some(1000.0), || {
            let mut acc = 0.0;
            for _ in 0..1000 {
                k += 1;
                hw.observe(10.0 + (k % 60) as f64 * 0.25, 1.0);
                acc += hw.forecast(60.0).unwrap_or(0.0);
            }
            black_box(acc);
        });
    }

    // -- worker-pool epoch overhead: the per-barrier fan-out cost -----------
    // The epoch driver publishes one pool job per tick barrier. This
    // isolates that per-barrier cost at shards=4 (100 barriers per
    // iteration, trivial per-shard work) and keeps the scoped-spawn
    // variant it replaced alongside, so the trajectory shows the win and
    // would expose a pool regression.
    // Registered unconditionally (unlike the core-gated shard benches):
    // this pair is on the CI gate's --require-file list, and both paths
    // degrade gracefully on a single-core runner.
    {
        let mut shards = [0u64; 4];
        let step = |i: usize, s: &mut u64| {
            *s = s
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(i as u64 + 1);
        };
        b.bench_units("parallel.pool_epoch shards=4 x100", Some(100.0), || {
            for _ in 0..100 {
                for_each_mut(4, &mut shards, step);
            }
            black_box(&shards);
        });
        b.bench_units("parallel.scoped_epoch shards=4 x100", Some(100.0), || {
            for _ in 0..100 {
                std::thread::scope(|scope| {
                    for (i, s) in shards.iter_mut().enumerate() {
                        scope.spawn(move || step(i, s));
                    }
                });
            }
            black_box(&shards);
        });
    }

    // -- streaming vs buffered summarization over 1M outcomes ---------------
    // The metrics half of the flat-memory hot path: folding completions
    // into `SummaryAccum` (what every run now does) against the buffered
    // `Summary::of` scan it must stay bit-identical to.
    {
        let outs: Vec<RequestOutcome> = (0..1_000_000u64)
            .map(|i| {
                let interactive = i % 3 != 0;
                let ttft = 0.2 + (i % 97) as f64 * 0.05;
                let itl = 0.02 + (i % 13) as f64 * 0.01;
                RequestOutcome {
                    id: RequestId(i),
                    class: if interactive {
                        RequestClass::Interactive
                    } else {
                        RequestClass::Batch
                    },
                    slo: if interactive {
                        Slo::interactive_default()
                    } else {
                        Slo::batch_default()
                    },
                    model: 0,
                    arrival: i as f64 * 1e-3,
                    first_token: i as f64 * 1e-3 + ttft,
                    completion: i as f64 * 1e-3 + ttft + itl * 100.0,
                    input_tokens: 128,
                    output_tokens: 100,
                    mean_itl: itl,
                    max_itl: itl * 2.0,
                    preemptions: (i % 11 == 0) as u32,
                    retries: 0,
                    phases: PhaseBreakdown::default(),
                }
            })
            .collect();
        b.bench_units("metrics.summary_1m buffered", Some(1e6), || {
            black_box(Summary::of(&outs).count);
        });
        b.bench_units("metrics.summary_1m streaming", Some(1e6), || {
            let mut acc = SummaryAccum::default();
            for o in &outs {
                acc.push(o);
            }
            black_box(acc.summary().count);
        });
    }

    // -- sharded event loop: 4 independent models between tick barriers -----
    // The same 4-model workload through the epoch driver at --shards 1 vs 4:
    // the trajectory tracks the shard-parallel speedup over PRs (results are
    // digest-identical either way — tests/sharding.rs proves it).
    {
        let models4 = vec![
            ModelSpec::llama8b(),
            ModelSpec::llama8b(),
            ModelSpec::llama8b(),
            ModelSpec::llama8b(),
        ];
        let mk = |models: &[ModelSpec]| {
            let mut rng = Rng::new(21);
            let mut tb = TraceBuilder::new();
            for m in 0..models.len() {
                tb = tb
                    .stream(workload_a(20.0, 500, m))
                    .stream(workload_b_batch(1000, 5.0, m, 1800.0));
            }
            tb.build(&mut rng)
        };
        // Built once, cloned per run: the timed region must be the event
        // loop, not trace generation, or the shards=1 vs shards=4 ratio
        // (the trajectory's speedup signal) is diluted by a constant.
        let trace = mk(&models4);
        let total = trace.len() as f64;
        let run_shards = |models4: &Vec<ModelSpec>, trace: chiron::workload::Trace, workers: usize| {
            let mut policy = Chiron::new(ChironConfig::for_models(4), models4);
            let mut cfg = SimConfig::new(48, models4.clone());
            cfg.max_sim_time = 4.0 * 3600.0;
            cfg.timeline_every = 0;
            cfg.shard_workers = workers;
            let r = run_sim(cfg, trace, &mut policy);
            black_box(r.outcomes.len());
        };
        b.bench_units("sim.shard_4models shards=1", Some(total), || {
            run_shards(&models4, trace.clone(), 1)
        });
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores > 1 {
            b.bench_units("sim.shard_4models shards=4", Some(total), || {
                run_shards(&models4, trace.clone(), 4)
            });
        }
    }

    // -- the 1M-request batch backlog through the sharded path --------------
    // Appendix A.2 at 1x scale: the acceptance macro-bench. One timed run
    // (bench_once): the streaming source keeps trace-side memory O(streams)
    // and the sharded engine drains the full million-request dump.
    {
        use chiron::workload::scenario::by_name;
        let spec = by_name("batch-backlog").expect("catalog scenario");
        let models_bb = spec.model_specs().expect("known models");
        let total = spec.max_requests() as f64;
        b.bench_once("sim.batch_backlog_1m", Some(total), || {
            let mut cfg = SimConfig::new(spec.gpus, models_bb.clone());
            cfg.max_sim_time = spec.max_time;
            cfg.timeline_every = 0;
            // Streaming-summary mode: the million-request dump must not
            // materialize a million `RequestOutcome`s (the summary
            // accumulators are bit-identical to the buffered path).
            cfg.keep_outcomes = false;
            let mut policy = Chiron::new(ChironConfig::for_models(1), &models_bb);
            let r = run_sim_source(cfg, Box::new(spec.source(1)), &mut policy);
            assert_eq!(r.unfinished, 0, "backlog must drain completely");
            assert!(r.outcomes.is_empty(), "streaming mode keeps no outcome buffer");
            black_box(r.stats.count());
        });
    }

    // -- the week-long 100M-request trace: the event-core scale target ------
    // week-diurnal-100m through the calendar core with sketch metrics and
    // streaming summaries: per-request memory is O(1), so the full week fits
    // in bounded memory. One timed run; quick mode scales the request caps
    // down (5e-5 → 5k requests) so CI records the entry on every push while
    // the full 100M run remains a local/nightly acceptance measurement.
    {
        use chiron::workload::scenario::by_name;
        let quick = std::env::var("CHIRON_BENCH_QUICK").is_ok();
        let spec = by_name("week-diurnal-100m")
            .expect("catalog scenario")
            .scaled(if quick { 5e-5 } else { 1.0 });
        let models_wk = spec.model_specs().expect("known models");
        let total = spec.max_requests() as f64;
        b.bench_once("sim.week_100m", Some(total), || {
            let mut cfg = SimConfig::new(spec.gpus, models_wk.clone());
            cfg.max_sim_time = spec.max_time;
            cfg.timeline_every = 0;
            cfg.keep_outcomes = false;
            cfg.sketch_metrics = true;
            // Pinned stepwise so this entry keeps its historical meaning
            // (the pre-fusion engine trajectory); the fused variant below
            // measures the macro-stepping win on the same week.
            cfg.fuse_steps = false;
            let mut policy = Chiron::new(ChironConfig::for_models(1), &models_wk);
            let r = run_sim_source(cfg, Box::new(spec.source(1)), &mut policy);
            assert!(r.outcomes.is_empty(), "sketch mode keeps no outcome buffer");
            black_box(r.stats.count());
        });
        // The same week with decode macro-stepping on (the shipping
        // default): quiescent night-trough and sparse-arrival stretches
        // collapse into fused steps, so the delta vs `sim.week_100m` is
        // the tentpole's week-scale event-traffic win.
        b.bench_once("sim.week_100m_fused", Some(total), || {
            let mut cfg = SimConfig::new(spec.gpus, models_wk.clone());
            cfg.max_sim_time = spec.max_time;
            cfg.timeline_every = 0;
            cfg.keep_outcomes = false;
            cfg.sketch_metrics = true;
            let mut policy = Chiron::new(ChironConfig::for_models(1), &models_wk);
            let r = run_sim_source(cfg, Box::new(spec.source(1)), &mut policy);
            assert!(r.steps_fused > 0, "the week hot path must fuse");
            black_box((r.stats.count(), r.steps_fused));
        });
    }

    // -- parallel grid: the four-policy compare() fan-out -------------------
    // Same grid at --jobs 1 vs --jobs N; the trajectory file records both,
    // so the speedup (ideally near-linear in cores) is tracked over PRs.
    {
        let kinds = vec![
            PolicyKind::Chiron,
            PolicyKind::LlumnixUntuned,
            PolicyKind::LocalOnly,
            PolicyKind::GlobalOnly(64),
        ];
        let models_grid = models.clone();
        let grid = |jobs_n: usize| {
            let tasks: Vec<&PolicyKind> = kinds.iter().collect();
            let done: usize = run_grid_jobs(jobs_n, tasks, |_, kind| {
                let mut rng = Rng::new(11);
                let trace = TraceBuilder::new()
                    .stream(workload_a(25.0, 700, 0))
                    .stream(workload_b_batch(1400, 5.0, 0, 1800.0))
                    .build(&mut rng);
                let mut p = make_policy(kind, &models_grid);
                let mut sim_cfg = SimConfig::new(50, models_grid.clone());
                sim_cfg.max_sim_time = 4.0 * 3600.0;
                sim_cfg.timeline_every = 0;
                run_sim(sim_cfg, trace, p.as_mut()).outcomes.len()
            })
            .into_iter()
            .sum();
            black_box(done);
        };
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(4);
        b.bench_units("grid.compare4 jobs=1", Some(4.0), || grid(1));
        if cores > 1 {
            b.bench_units(&format!("grid.compare4 jobs={cores}"), Some(4.0), || {
                grid(cores)
            });
        }
    }

    b.report();

    // Machine-readable perf trajectory at the repo root (BENCH_hotpath.json)
    // so this and future PRs can prove regressions/improvements.
    let out = std::env::var("CHIRON_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json").into());
    b.write_json(&out);
}
