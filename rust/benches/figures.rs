//! End-to-end figure benches: `cargo bench --bench figures` regenerates
//! every paper table and figure in quick mode and times each one. This is
//! the "one bench per paper table" harness entry point; the figures
//! themselves print the same rows/series the paper reports and save JSON
//! under results/. Use `cargo run --release -- experiment all` for
//! full-scale runs.
//!
//! Each figure's wall time is appended to the `BENCH_hotpath.json`
//! trajectory as `figures.<id>` (one timed pass per figure — these are
//! multi-second macro benches, so variance is left unmeasured rather than
//! paid for), letting PRs track end-to-end harness cost alongside the
//! hot-path micro benches. Runs land as separate trajectory entries from
//! the hotpath bench, and the CI gate ignores them (it pins specific bench
//! names and skips runs that lack them).

use chiron::experiments::{self, common::Scale};
use chiron::util::bench::Bencher;

fn main() {
    // This bench always runs Scale::Quick, so label the trajectory entry
    // accordingly regardless of how it was invoked — bench-gate's
    // comparability rule (same quick flag) must never pair these timings
    // with full-mode history.
    std::env::set_var("CHIRON_BENCH_QUICK", "1");
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let mut b = Bencher::new();
    let mut total = 0.0;
    for id in experiments::ALL {
        if let Some(f) = &filter {
            if !id.contains(f.as_str()) {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        b.bench_once(&format!("figures.{id}"), None, || {
            experiments::run(id, Scale::Quick).expect("known id");
        });
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("[bench {id}: {dt:.2}s]\n");
    }
    println!("== figures bench total: {total:.1}s ==");
    b.report();
    let out = std::env::var("CHIRON_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json").into());
    b.write_json(&out);
}
