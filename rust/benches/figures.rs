//! End-to-end figure benches: `cargo bench --bench figures` regenerates
//! every paper table and figure in quick mode and times each one. This is
//! the "one bench per paper table" harness entry point; the figures
//! themselves print the same rows/series the paper reports and save JSON
//! under results/. Use `cargo run --release -- experiment all` for
//! full-scale runs.

use chiron::experiments::{self, common::Scale};

fn main() {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let mut total = 0.0;
    for id in experiments::ALL {
        if let Some(f) = &filter {
            if !id.contains(f.as_str()) {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        experiments::run(id, Scale::Quick).expect("known id");
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("[bench {id}: {dt:.2}s]\n");
    }
    println!("== figures bench total: {total:.1}s ==");
}
