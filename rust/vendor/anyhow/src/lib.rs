//! Offline stand-in for the `anyhow` crate (the sandbox vendors no
//! registry crates). Implements the subset chiron uses: `Error` with a
//! context chain, `Result`, the `anyhow!` / `bail!` / `ensure!` macros, and
//! the `Context` extension trait for `Result` and `Option`.
//!
//! Formatting follows real anyhow: `{}` prints the outermost message,
//! `{:#}` prints the whole chain separated by `: `, and `{:?}` prints the
//! message plus a `Caused by:` list.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain outermost-first.
    fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, so the blanket conversion below does not collide
// with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context frames.
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in frames.into_iter().rev() {
            err = Some(match err.take() {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.expect("at least one frame")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...{}...", args)` — construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!(...)` — return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading config".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(g().unwrap_err().to_string(), "missing");
    }
}
