//! Offline stub of the `xla` crate's PJRT API surface used by
//! `chiron::runtime`. The sandbox has no PJRT dependency closure, so this
//! stub keeps the crate compiling and the test suite green: every entry
//! point that would touch XLA returns `Err(XlaError)` from
//! `PjRtClient::cpu()` onward, and the runtime integration tests already
//! skip when artifacts are absent. Swap this crate for the real `xla`
//! dependency (same API) to run the real-engine path.

use std::path::Path;

/// Error type; call sites format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT backend unavailable: built with the offline `xla` stub (see rust/vendor/xla)"
            .to_string(),
    ))
}

/// Stub PJRT client: creation always fails, so no downstream stub method is
/// reachable in practice.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// Stub XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Real signature executes device buffers; the stub accepts any input
    /// slice type (chiron passes `&[Literal]`).
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}
