//! Batch-pipeline scenario (the paper's motivating background workload):
//! a document-processing job dumps a large batch queue with a deadline
//! while an interactive service keeps running. Shows Chiron queuing the
//! batch work, multiplexing it onto over-provisioned mixed instances, and
//! adding batch instances only when the waiting-time estimator says the
//! deadline is at risk (Algorithm 2).
//!
//! Run: `cargo run --release --example batch_pipeline`

use chiron::coordinator::{BootstrapSpec, Chiron, ChironConfig};
use chiron::core::{ModelSpec, RequestClass, Slo};
use chiron::metrics::{PolicyRow, Summary};
use chiron::sim::{run_sim, SimConfig};
use chiron::util::rng::Rng;
use chiron::workload::{ArrivalProcess, ShareGptSampler, TraceBuilder, WorkloadSpec};

fn main() {
    let models = vec![ModelSpec::llama8b()];
    let deadline_s = 1800.0; // 30-minute batch deadline

    let mut rng = Rng::new(77);
    let trace = TraceBuilder::new()
        .sampler(ShareGptSampler::new())
        // Interactive service: 20 req/s throughout.
        .stream(WorkloadSpec {
            class: RequestClass::Interactive,
            slo: Slo::interactive_default(),
            arrivals: ArrivalProcess::Poisson { rate: 20.0 },
            count: 3000,
            model: 0,
            start: 0.0,
        })
        // Document-processing job: 10k requests land at t = 60 s.
        .stream(WorkloadSpec {
            class: RequestClass::Batch,
            slo: Slo {
                ttft: deadline_s,
                ..Slo::batch_default()
            },
            arrivals: ArrivalProcess::Burst { at: 60.0 },
            count: 10_000,
            model: 0,
            start: 60.0,
        })
        .build(&mut rng);
    println!(
        "batch pipeline: {} interactive + {} batch requests, deadline {}s",
        trace.count_class(RequestClass::Interactive),
        trace.count_class(RequestClass::Batch),
        deadline_s
    );

    let mut cfg = ChironConfig::for_models(1);
    cfg.bootstrap[0] = BootstrapSpec {
        interactive: 1,
        mixed: 2,
        batch: 0,
    };
    let mut policy = Chiron::new(cfg, &models);
    let mut sim_cfg = SimConfig::new(50, models.clone());
    sim_cfg.max_sim_time = 2.0 * 3600.0;
    sim_cfg.timeline_every = 30;
    let report = run_sim(sim_cfg, trace, &mut policy);

    println!("\n{}", PolicyRow::header());
    println!("{}", PolicyRow::from_report(&report).line());

    println!("\ntimeline (every ~5 min): GPUs / batch instances / queued batch");
    for p in report.timeline.iter().step_by(10) {
        println!(
            "  t={:>6.0}s gpus={:>2} batch_inst={:>2} queue={:>6} batch_size~{:>5.0}",
            p.t, p.gpus_used, p.instances_batch, p.queued_batch, p.mean_max_batch
        );
    }

    let batch_summary = Summary::of_class(&report.outcomes, RequestClass::Batch);
    let inter_summary = Summary::of_class(&report.outcomes, RequestClass::Interactive);
    println!(
        "\ninteractive: {:.1}% SLO, ttft p99 {:.2}s | batch: {:.1}% SLO, ttft p99 {:.0}s (deadline {}s)",
        inter_summary.slo_attainment * 100.0,
        inter_summary.ttft_p99,
        batch_summary.slo_attainment * 100.0,
        batch_summary.ttft_p99,
        deadline_s
    );
    assert!(report.unfinished == 0, "pipeline must drain");
}
