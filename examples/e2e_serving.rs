//! End-to-end driver (the EXPERIMENTS.md §E2E run): serve a real batched
//! workload on the AOT-compiled tiny model through the full stack —
//! Rust front-end → continuous-batching engine → PJRT → HLO (with the
//! Pallas decode-attention kernel inside) — with the Chiron local
//! autoscaler (Algorithm 1) live-controlling the engine batch size.
//!
//! Reports latency/throughput at several offered loads, and contrasts a
//! static conservative batch size with the autoscaled engine.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use chiron::coordinator::{LocalAutoscaler, LocalConfig};
use chiron::core::{InstanceClass, InstanceId};
use chiron::engine::{EngineOutcome, EngineRequest, EngineStats, LlmEngine};
use chiron::runtime::TinyLlmRuntime;
use chiron::server::{BatchController, ServingFrontend};
use chiron::sim::policy::{InstanceState, InstanceView};
use chiron::util::rng::Rng;
use chiron::util::stats::Percentiles;
use chiron::workload::ShareGptSampler;

const ITL_SLO: f64 = 0.05; // 50 ms per token on this CPU-scale model

fn controller() -> BatchController {
    let mut la = LocalAutoscaler::new(LocalConfig {
        default_itl_slo: ITL_SLO,
        ..LocalConfig::default()
    });
    Box::new(move |st: &EngineStats| {
        let v = InstanceView {
            id: InstanceId(0),
            class: InstanceClass::Mixed,
            model: 0,
            state: InstanceState::Running,
            running: st.running as u32,
            running_interactive: st.running as u32,
            waiting: st.waiting as u32,
            max_batch: st.max_batch as u32,
            kv_tokens: 0,
            kv_capacity: 1,
            last_step_time: st.last_step_time,
            last_decode_time: st.last_step_time,
            throughput_tokens: if st.last_step_time > 0.0 {
                st.running as f64 / st.last_step_time
            } else {
                0.0
            },
            min_itl_slo: ITL_SLO,
            steps: st.steps,
        };
        la.on_step(&v).map(|b| (b as usize).min(8))
    })
}

struct RunResult {
    label: String,
    offered_rate: f64,
    achieved_rps: f64,
    tok_per_s: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    itl_mean_ms: f64,
    final_batch: usize,
}

fn run_load(
    label: &str,
    rate: f64,
    n: usize,
    initial_batch: usize,
    autoscale: bool,
    seed: u64,
) -> anyhow::Result<RunResult> {
    let front = ServingFrontend::start(
        move || Ok(LlmEngine::new(TinyLlmRuntime::load("artifacts")?, initial_batch)),
        if autoscale { Some(controller()) } else { None },
    );
    let sampler = ShareGptSampler::tiny();
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let (ilen, olen) = sampler.sample(&mut rng);
        let prompt: Vec<i32> = (0..ilen).map(|_| rng.index(255) as i32 + 1).collect();
        front.submit(EngineRequest {
            id: i as u64,
            prompt,
            max_new_tokens: (olen as usize).min(48),
            arrival: None,
        })?;
        // Open-loop Poisson offered load.
        let gap = rng.exp(rate);
        std::thread::sleep(std::time::Duration::from_secs_f64(gap));
    }
    let outcomes: Vec<EngineOutcome> = front.wait_for(n, std::time::Duration::from_secs(900));
    let wall = t0.elapsed().as_secs_f64();
    let final_batch = front.stats().max_batch;
    front.shutdown()?;

    let total_tokens: usize = outcomes.iter().map(|o| o.tokens.len()).sum();
    let mut ttft = Percentiles::new();
    for o in &outcomes {
        ttft.push(o.ttft * 1000.0);
    }
    let itl_mean =
        outcomes.iter().map(|o| o.mean_itl).sum::<f64>() / outcomes.len().max(1) as f64;
    Ok(RunResult {
        label: label.to_string(),
        offered_rate: rate,
        achieved_rps: outcomes.len() as f64 / wall,
        tok_per_s: total_tokens as f64 / wall,
        ttft_p50_ms: ttft.pct(50.0),
        ttft_p99_ms: ttft.pct(99.0),
        itl_mean_ms: itl_mean * 1000.0,
        final_batch,
    })
}

fn main() -> anyhow::Result<()> {
    if chiron::runtime::Manifest::load("artifacts").is_err() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("end-to-end serving on the real AOT model (Pallas decode attention inside)\n");
    let n = 48;
    let mut results = Vec::new();
    for &(label, rate, init_b, auto) in &[
        ("static-b1", 4.0, 1usize, false),
        ("static-b8", 4.0, 8, false),
        ("autoscaled", 4.0, 2, true),
        ("autoscaled", 10.0, 2, true),
        ("autoscaled", 24.0, 2, true),
    ] {
        let r = run_load(label, rate, n, init_b, auto, 11)?;
        println!(
            "{:<12} offered {:>5.1}/s -> {:>5.1} req/s, {:>6.0} tok/s, ttft p50 {:>7.1} ms p99 {:>8.1} ms, itl {:>5.2} ms, final batch {}",
            r.label, r.offered_rate, r.achieved_rps, r.tok_per_s, r.ttft_p50_ms, r.ttft_p99_ms, r.itl_mean_ms, r.final_batch
        );
        results.push(r);
    }
    // The autoscaled engine should beat the conservative static batch on
    // throughput at saturating load.
    let static1 = results.iter().find(|r| r.label == "static-b1").unwrap();
    let auto_hi = results.last().unwrap();
    println!(
        "\nautoscaled vs static-b1 token throughput: {:.2}x",
        auto_hi.tok_per_s / static1.tok_per_s.max(1e-9)
    );
    Ok(())
}
