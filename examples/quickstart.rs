//! Quickstart: the smallest end-to-end tour of the library.
//!
//! 1. Generate a mixed interactive+batch workload trace.
//! 2. Run it through the discrete-event cluster simulator under Chiron.
//! 3. Compare with the Llumnix-like baseline.
//! 4. If AOT artifacts exist (`make artifacts`), serve a few requests on
//!    the real PJRT-backed engine too.
//!
//! Run: `cargo run --release --example quickstart`

use chiron::baselines::Llumnix;
use chiron::coordinator::{BootstrapSpec, Chiron, ChironConfig};
use chiron::core::ModelSpec;
use chiron::engine::{EngineRequest, LlmEngine};
use chiron::metrics::PolicyRow;
use chiron::runtime::TinyLlmRuntime;
use chiron::server::ServingFrontend;
use chiron::sim::{run_sim, SimConfig};
use chiron::util::rng::Rng;
use chiron::workload::trace::{workload_a, workload_b_batch};
use chiron::workload::TraceBuilder;

fn main() -> anyhow::Result<()> {
    // --- 1. workload -----------------------------------------------------
    let models = vec![ModelSpec::llama8b()];
    let mk_trace = |seed: u64| {
        let mut rng = Rng::new(seed);
        TraceBuilder::new()
            .stream(workload_a(25.0, 1500, 0)) // interactive, 25 req/s
            .stream(workload_b_batch(3000, 30.0, 0, 1800.0)) // batch burst
            .build(&mut rng)
    };
    println!("trace: {} requests", mk_trace(7).len());

    // --- 2. Chiron -------------------------------------------------------
    let mut cfg = ChironConfig::for_models(1);
    cfg.bootstrap[0] = BootstrapSpec {
        interactive: 1,
        mixed: 2,
        batch: 0,
    };
    let mut chiron = Chiron::new(cfg, &models);
    let mut sim_cfg = SimConfig::new(50, models.clone());
    sim_cfg.max_sim_time = 4.0 * 3600.0;
    let r_chiron = run_sim(sim_cfg.clone(), mk_trace(7), &mut chiron);

    // --- 3. baseline -----------------------------------------------------
    let mut llumnix = Llumnix::untuned(&models);
    let r_llumnix = run_sim(sim_cfg, mk_trace(7), &mut llumnix);

    println!("\n{}", PolicyRow::header());
    println!("{}", PolicyRow::from_report(&r_chiron).line());
    println!("{}", PolicyRow::from_report(&r_llumnix).line());
    println!(
        "\nGPU·h: chiron {:.2} vs llumnix {:.2} ({:.0}% saved)",
        r_chiron.gpu_seconds / 3600.0,
        r_llumnix.gpu_seconds / 3600.0,
        (1.0 - r_chiron.gpu_seconds / r_llumnix.gpu_seconds.max(1e-9)) * 100.0
    );

    // --- 4. real engine (optional) ----------------------------------------
    match chiron::runtime::Manifest::load("artifacts") {
        Err(_) => println!("\n(real-engine demo skipped: run `make artifacts` first)"),
        Ok(_) => {
            println!("\nserving 8 requests on the real AOT model ...");
            let front = ServingFrontend::start(
                || Ok(LlmEngine::new(TinyLlmRuntime::load("artifacts")?, 4)),
                None,
            );
            for i in 0..8u64 {
                front.submit(EngineRequest {
                    id: i,
                    prompt: vec![1 + i as i32, 2, 3, 4],
                    max_new_tokens: 8,
                    arrival: None,
                })?;
            }
            let done = front.wait_for(8, std::time::Duration::from_secs(120));
            for o in &done {
                println!(
                    "  req{}: {} tokens, ttft {:.1} ms, itl {:.2} ms",
                    o.id,
                    o.tokens.len(),
                    o.ttft * 1000.0,
                    o.mean_itl * 1000.0
                );
            }
            front.shutdown()?;
        }
    }
    Ok(())
}
