//! Cluster-scale autoscaling demo: replay a bursty day-in-the-life
//! workload (Gamma arrivals + periodic batch jobs) across the full policy
//! set and print a comparative report — the kind of study an operator
//! would run before choosing an autoscaler.
//!
//! Run: `cargo run --release --example autoscale_sim`

use chiron::baselines::{GlobalOnly, Llumnix, LlumnixConfig, LocalOnly};
use chiron::coordinator::{BootstrapSpec, Chiron, ChironConfig};
use chiron::core::{ModelSpec, RequestClass, Slo};
use chiron::metrics::PolicyRow;
use chiron::sim::{run_sim, Policy, SimConfig};
use chiron::util::rng::Rng;
use chiron::workload::{ArrivalProcess, ShareGptSampler, Trace, TraceBuilder, WorkloadSpec};

fn day_trace(models: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut tb = TraceBuilder::new().sampler(ShareGptSampler::new());
    for m in 0..models {
        // Bursty interactive traffic with a lunchtime peak.
        tb = tb.stream(WorkloadSpec {
            class: RequestClass::Interactive,
            slo: Slo::interactive_default(),
            arrivals: ArrivalProcess::Phased {
                segments: vec![(0.0, 12.0), (1200.0, 35.0), (2600.0, 15.0)],
            },
            count: 2500 / (m + 1),
            model: m,
            start: 0.0,
        });
        // Two batch jobs with different deadlines.
        tb = tb.stream(WorkloadSpec {
            class: RequestClass::Batch,
            slo: Slo {
                ttft: 1800.0,
                ..Slo::batch_default()
            },
            arrivals: ArrivalProcess::Burst { at: 600.0 },
            count: 3000 / (m + 1),
            model: m,
            start: 600.0,
        });
        tb = tb.stream(WorkloadSpec {
            class: RequestClass::Batch,
            slo: Slo {
                ttft: 3600.0,
                ..Slo::batch_default()
            },
            arrivals: ArrivalProcess::Burst { at: 1500.0 },
            count: 4000 / (m + 1),
            model: m,
            start: 1500.0,
        });
    }
    tb.build(&mut rng)
}

fn main() {
    let models = vec![ModelSpec::llama8b(), ModelSpec::llama70b()];
    let mut sim_cfg = SimConfig::new(50, models.clone());
    sim_cfg.max_sim_time = 4.0 * 3600.0;

    let mut chiron_cfg = ChironConfig::for_models(models.len());
    for b in &mut chiron_cfg.bootstrap {
        *b = BootstrapSpec {
            interactive: 1,
            mixed: 2,
            batch: 0,
        };
    }

    let mut policies: Vec<Box<dyn Policy>> = vec![
        Box::new(Chiron::new(chiron_cfg.clone(), &models)),
        Box::new(Llumnix::untuned(&models)),
        Box::new(Llumnix::tuned(
            &models,
            LlumnixConfig {
                max_batch: 256,
                low: 0.2,
                high: 0.7,
                ..LlumnixConfig::untuned()
            },
        )),
        Box::new(LocalOnly::new(&models, LlumnixConfig::untuned())),
        Box::new(GlobalOnly::new(&models, chiron_cfg, 64)),
    ];

    println!("day-in-the-life workload: {} requests over ~1h (2 models)\n", day_trace(2, 3).len());
    println!("{}", PolicyRow::header());
    let mut rows = Vec::new();
    for p in policies.iter_mut() {
        let report = run_sim(sim_cfg.clone(), day_trace(2, 3), p.as_mut());
        let row = PolicyRow::from_report(&report);
        println!("{}", row.line());
        rows.push(row);
    }
    let chiron_row = &rows[0];
    let best_other_gpuh = rows[1..]
        .iter()
        .map(|r| r.gpu_hours)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nchiron GPU·h {:.2} vs best baseline {:.2} ({:+.0}%), SLO {:.1}%",
        chiron_row.gpu_hours,
        best_other_gpuh,
        (chiron_row.gpu_hours / best_other_gpuh - 1.0) * 100.0,
        chiron_row.slo_attainment * 100.0
    );
}
