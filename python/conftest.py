# Make `compile.*` importable whether pytest runs from the repo root
# (`pytest python/tests`) or from python/ (`python -m pytest tests`).
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
