"""AOT lowering: JAX functions → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (one per function × batch variant):
  artifacts/prefill_b{B}.hlo.txt   (tokens[B,S], lengths[B]) -> (logits, cache)
  artifacts/decode_b{B}.hlo.txt    (tokens[B], pos[B], cache) -> (logits, cache)
  artifacts/manifest.json          shapes + model config for the Rust side

Run via `make artifacts` (no-op when inputs are unchanged). Python never
runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import DEFAULT_CONFIG, build_fns
from compile.kernels.decode_attention import vmem_report

BATCH_VARIANTS = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side).

    print_large_constants=True is essential: the baked model weights are HLO
    constants, and the default printer elides them as `constant({...})`,
    which would silently load as garbage on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_all(out_dir: str, seed: int = 0):
    cfg = DEFAULT_CONFIG
    prefill_fn, decode_fn = build_fns(cfg, seed)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "d_head": cfg.d_head,
            "seed": seed,
        },
        "batch_variants": BATCH_VARIANTS,
        "artifacts": {},
    }

    for b in BATCH_VARIANTS:
        tok_p = jax.ShapeDtypeStruct((b, cfg.max_seq), jnp.int32)
        len_p = jax.ShapeDtypeStruct((b,), jnp.int32)
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, 2, b, cfg.max_seq, cfg.n_heads, cfg.d_head),
            jnp.float32,
        )
        tok_d = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos_d = jax.ShapeDtypeStruct((b,), jnp.int32)

        pre = jax.jit(prefill_fn).lower(tok_p, len_p)
        dec = jax.jit(decode_fn).lower(tok_d, pos_d, cache)

        pre_path = f"prefill_b{b}.hlo.txt"
        dec_path = f"decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, pre_path), "w") as f:
            f.write(to_hlo_text(pre))
        with open(os.path.join(out_dir, dec_path), "w") as f:
            f.write(to_hlo_text(dec))
        manifest["artifacts"][str(b)] = {
            "prefill": pre_path,
            "decode": dec_path,
            "cache_shape": list(cache.shape),
        }
        print(f"lowered batch={b}: {pre_path}, {dec_path}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


def report():
    """§Perf L1 structural profile: VMEM footprint + MXU utilization
    estimates for the decode-attention BlockSpec across batch variants."""
    cfg = DEFAULT_CONFIG
    print("decode_attention kernel — per-grid-step estimates")
    print(f"{'B':>4} {'VMEM/step':>12} {'FLOPs/step':>12} {'MXU tile util':>14}")
    for b in BATCH_VARIANTS:
        r = vmem_report(b, cfg.max_seq, cfg.n_heads, cfg.d_head)
        print(
            f"{b:>4} {r['vmem_mib_per_step']:>10.3f}Mi "
            f"{r['flops_per_step']:>12} {r['mxu_tile_utilization']:>14.4f}"
        )
    print(r["notes"])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", action="store_true",
                    help="print the L1 VMEM/MXU structural profile and exit")
    args = ap.parse_args()
    if args.report:
        report()
        return
    lower_all(args.out, args.seed)


if __name__ == "__main__":
    main()
