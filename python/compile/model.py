"""L2: the serving model — a small decoder-only transformer in JAX.

The paper serves Llama-3.1-8B/70B on vLLM; the real-execution path here
serves this ~1M-parameter transformer so the full stack (Rust coordinator →
PJRT → HLO → Pallas kernel) is exercised end to end on CPU. Architecture
follows the Llama shape at toy scale: RMSNorm → multi-head attention (the
L1 Pallas decode-attention kernel on the decode path) → SwiGLU MLP, learned
positional embeddings, functional KV cache threaded in/out of `decode_step`.

Weights are generated deterministically (PRNGKey(0)) and baked into the HLO
as constants by `aot.py`, so the Rust runtime loads a single self-contained
artifact per (function, batch) variant.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import decode_attention


class ModelConfig(NamedTuple):
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 192
    max_seq: int = 128

    @property
    def d_head(self):
        return self.d_model // self.n_heads


DEFAULT_CONFIG = ModelConfig()


def init_params(cfg: ModelConfig = DEFAULT_CONFIG, seed: int = 0):
    """Deterministic toy-scale parameters."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 4 + 7 * cfg.n_layers))

    def mat(shape, scale=None):
        k = next(keys)
        scale = scale or (1.0 / (shape[0] ** 0.5))
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    params = {
        "tok_emb": mat((cfg.vocab, cfg.d_model), 0.02),
        "pos_emb": mat((cfg.max_seq, cfg.d_model), 0.02),
        "out_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": mat((cfg.d_model, cfg.vocab)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "wq": mat((cfg.d_model, cfg.d_model)),
            "wk": mat((cfg.d_model, cfg.d_model)),
            "wv": mat((cfg.d_model, cfg.d_model)),
            "wo": mat((cfg.d_model, cfg.d_model)),
            "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "w_gate": mat((cfg.d_model, cfg.d_ff)),
            "w_up": mat((cfg.d_model, cfg.d_ff)),
            "w_down": mat((cfg.d_ff, cfg.d_model)),
        })
    return params


def rmsnorm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _split_heads(x, cfg):
    # [..., d_model] -> [..., H, Dh]
    return x.reshape(x.shape[:-1] + (cfg.n_heads, cfg.d_head))


def _merge_heads(x):
    return x.reshape(x.shape[:-2] + (-1,))


def empty_cache(cfg: ModelConfig, batch: int):
    """KV cache: [n_layers, 2(k/v), B, S, H, Dh] f32."""
    return jnp.zeros(
        (cfg.n_layers, 2, batch, cfg.max_seq, cfg.n_heads, cfg.d_head),
        jnp.float32,
    )


def prefill(params, cfg: ModelConfig, tokens, lengths):
    """Process padded prompts, build the KV cache, return first-token logits.

    Args:
      tokens:  [B, S] int32, right-padded with zeros.
      lengths: [B] int32 valid prompt lengths.
    Returns:
      logits [B, vocab] at each row's last valid position, cache.
    """
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    cache = empty_cache(cfg, b)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    pad = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S]
    mask = causal[None, None, :, :] & pad[:, None, None, :]  # [B, 1, S, S]

    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = _split_heads(h @ layer["wq"], cfg)  # [B, S, H, Dh]
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        # Full prefill attention (dense, jnp — prefill is compute-bound and
        # XLA fuses it well; the Pallas kernel owns the decode hot loop).
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (cfg.d_head ** 0.5)
        scores = jnp.where(mask, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        x = x + _merge_heads(attn) @ layer["wo"]
        h2 = rmsnorm(x, layer["mlp_norm"])
        x = x + swiglu(h2, layer["w_gate"], layer["w_up"], layer["w_down"])

        kpad = jnp.pad(k, ((0, 0), (0, cfg.max_seq - s), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, cfg.max_seq - s), (0, 0), (0, 0)))
        cache = cache.at[li, 0].set(kpad)
        cache = cache.at[li, 1].set(vpad)

    x = rmsnorm(x, params["out_norm"])
    logits_all = x @ params["lm_head"]  # [B, S, vocab]
    idx = jnp.clip(lengths - 1, 0, s - 1)
    logits = jnp.take_along_axis(
        logits_all, idx[:, None, None].repeat(1, axis=1), axis=1
    )[:, 0, :]
    return logits, cache


def decode_step(params, cfg: ModelConfig, tokens, positions, cache):
    """One decode step for a batch of sequences.

    Args:
      tokens:    [B] int32 current input token per row.
      positions: [B] int32 position of that token (0-based).
      cache:     [L, 2, B, S, H, Dh] KV cache (functional, returned updated).
    Returns:
      logits [B, vocab], updated cache.
    """
    b = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]  # [B, D]

    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = _split_heads(h @ layer["wq"], cfg)  # [B, H, Dh]
        k_new = _split_heads(h @ layer["wk"], cfg)
        v_new = _split_heads(h @ layer["wv"], cfg)

        # Scatter this step's K/V into the cache at each row's position.
        rows = jnp.arange(b)
        cache = cache.at[li, 0, rows, positions].set(k_new)
        cache = cache.at[li, 1, rows, positions].set(v_new)

        # L1 Pallas kernel: masked decode attention over the padded cache.
        attn = decode_attention(q, cache[li, 0], cache[li, 1], positions + 1)
        x = x + _merge_heads(attn) @ layer["wo"]
        h2 = rmsnorm(x, layer["mlp_norm"])
        x = x + swiglu(h2, layer["w_gate"], layer["w_up"], layer["w_down"])

    x = rmsnorm(x, params["out_norm"])
    return x @ params["lm_head"], cache


def build_fns(cfg: ModelConfig = DEFAULT_CONFIG, seed: int = 0):
    """Closure-bound (prefill, decode_step) with weights baked in."""
    params = init_params(cfg, seed)

    @jax.jit
    def prefill_fn(tokens, lengths):
        return prefill(params, cfg, tokens, lengths)

    @jax.jit
    def decode_fn(tokens, positions, cache):
        return decode_step(params, cfg, tokens, positions, cache)

    return prefill_fn, decode_fn


@functools.lru_cache(maxsize=4)
def cached_fns(seed: int = 0):
    return build_fns(DEFAULT_CONFIG, seed)
