"""Pure-jnp oracle for the Pallas decode-attention kernel.

This is the correctness ground truth: no Pallas, no tiling — the textbook
masked attention computation. pytest asserts allclose between
`decode_attention` (kernel) and `decode_attention_ref` across shapes and
dtypes (hypothesis sweep in python/tests/test_kernel.py).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths):
    """Reference masked batched decode attention.

    Args:
      q:       [B, H, D]
      k, v:    [B, S, H, D]
      lengths: [B] int32 valid context lengths (<= S).
    Returns:
      [B, H, D] in q.dtype.
    """
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    s = k.shape[1]
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
