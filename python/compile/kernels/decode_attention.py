"""L1: batched decode attention as a Pallas kernel.

The serving hot-spot: at every decode step each running request attends its
single new query vector against its full KV context. The CUDA systems the
paper builds on (vLLM's PagedAttention) schedule this per-threadblock over
KV pages in HBM; the TPU-style rethink here (DESIGN.md §Hardware-Adaptation)
stages one request's padded K/V context block into VMEM via BlockSpec, runs
the q·Kᵀ reduction as a dense MXU-friendly matmul over the padded window,
and replaces the page table with an explicit validity mask derived from the
context length.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO (see /opt/xla-example/README.md). Numeric parity with the pure-jnp
oracle (`ref.py`) is enforced by pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # large-negative mask value, safe in f32 and bf16


def _decode_attention_kernel(len_ref, q_ref, k_ref, v_ref, o_ref):
    """One grid step = one batch row.

    Block shapes (leading batch dim of 1 squeezed inside):
      len_ref: [1]        int32 valid context length for this row
      q_ref:   [1, H, D]
      k_ref:   [1, S, H, D]   (padded context window, resident in VMEM)
      v_ref:   [1, S, H, D]
      o_ref:   [1, H, D]
    """
    q = q_ref[0]  # [H, D]
    k = k_ref[0]  # [S, H, D]
    v = v_ref[0]  # [S, H, D]
    length = len_ref[0]

    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)

    # Scores over the padded window: [H, S]. On TPU this is the MXU matmul;
    # computing over the fixed window (not a dynamic slice) keeps the shape
    # static for the systolic array.
    scores = jnp.einsum("hd,shd->hs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale

    # Validity mask replaces PagedAttention's page table: positions past the
    # row's context length contribute nothing.
    mask = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) < length
    scores = jnp.where(mask, scores, NEG_INF)

    # Numerically stable softmax in f32.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    # Weighted value gather: [H, S] x [S, H, D] -> [H, D].
    out = jnp.einsum("hs,shd->hd", p, v.astype(jnp.float32))
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def decode_attention(q, k, v, lengths):
    """Masked batched decode attention.

    Args:
      q:       [B, H, D]  query for the current decode position.
      k, v:    [B, S, H, D]  padded KV context.
      lengths: [B] int32, valid context length per row (<= S).

    Returns:
      [B, H, D] attention output, dtype of q.
    """
    b, h, d = q.shape
    s = k.shape[1]
    return pl.pallas_call(
        _decode_attention_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, h, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s, h, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(lengths, q, k, v)


def vmem_report(b, s, h, d, dtype_bytes=4):
    """Estimated per-grid-step VMEM footprint and MXU utilization for the
    chosen BlockSpec (the §Perf L1 structural profile — interpret mode has
    no real TPU timings, so we report the roofline-relevant quantities)."""
    q_bytes = h * d * dtype_bytes
    kv_bytes = 2 * s * h * d * dtype_bytes
    scores_bytes = h * s * 4  # f32 accumulation
    out_bytes = h * d * dtype_bytes
    total = q_bytes + kv_bytes + scores_bytes + out_bytes
    # MXU does [H,D]x[D,S] and [H,S]x[S,D]; utilization vs the 128x128 array:
    mxu_m = min(h, 128) / 128.0
    mxu_k = min(d, 128) / 128.0
    flops = 2 * h * s * d * 2  # two einsums
    return {
        "grid": b,
        "vmem_bytes_per_step": total,
        "vmem_mib_per_step": total / (1 << 20),
        "flops_per_step": flops,
        "mxu_tile_utilization": mxu_m * mxu_k,
        "notes": "K/V context staged per-row; fits VMEM (<1 MiB) for S<=128,"
                 " H*D<=1024",
    }
