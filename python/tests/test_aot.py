"""AOT pipeline checks: HLO text artifacts are complete (no elided
constants), entries have the runtime-visible signature, and the manifest is
consistent. Uses a temp dir so it does not clobber `make artifacts` output."""

import json
import os
import re

import pytest

from compile.aot import BATCH_VARIANTS, lower_all, to_hlo_text
from compile.model import DEFAULT_CONFIG, build_fns

import jax
import jax.numpy as jnp


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    lower_all(out, seed=0)
    return out


def test_manifest_lists_all_variants(artifacts):
    m = json.load(open(os.path.join(artifacts, "manifest.json")))
    assert m["batch_variants"] == BATCH_VARIANTS
    for b in BATCH_VARIANTS:
        entry = m["artifacts"][str(b)]
        for kind in ("prefill", "decode"):
            path = os.path.join(artifacts, entry[kind])
            assert os.path.exists(path), path
            assert os.path.getsize(path) > 10_000
    assert m["model"]["vocab"] == DEFAULT_CONFIG.vocab
    assert m["model"]["max_seq"] == DEFAULT_CONFIG.max_seq


def test_no_elided_constants(artifacts):
    """`constant({...})` means weights were dropped by the printer — the
    Rust runtime would silently compute garbage."""
    for fname in os.listdir(artifacts):
        if fname.endswith(".hlo.txt"):
            text = open(os.path.join(artifacts, fname)).read()
            assert "constant({...})" not in text, fname


def test_entry_signatures(artifacts):
    cfg = DEFAULT_CONFIG
    for b in BATCH_VARIANTS:
        text = open(os.path.join(artifacts, f"decode_b{b}.hlo.txt")).read()
        entry = text[text.index("ENTRY"):]
        params = re.findall(r"= (\S+) parameter\(\d+\)",
                            entry.split("ROOT")[0])
        assert params[0] == f"s32[{b}]{{0}}"  # tokens
        assert params[1] == f"s32[{b}]{{0}}"  # positions
        assert params[2].startswith(
            f"f32[{cfg.n_layers},2,{b},{cfg.max_seq},{cfg.n_heads},{cfg.d_head}]"
        )  # cache


def test_weights_are_baked(artifacts):
    """The token-embedding constant (vocab × d_model floats) must be present
    inline — its raw text alone is hundreds of KB."""
    text = open(os.path.join(artifacts, "decode_b1.hlo.txt")).read()
    cfg = DEFAULT_CONFIG
    assert f"f32[{cfg.vocab},{cfg.d_model}]" in text
    assert len(text) > 1_000_000  # full constants, not elided


def test_hlo_text_is_parseable_roundtrip():
    """Sanity: the text we emit is valid HLO the XLA parser accepts (the
    same parser the Rust xla crate uses)."""
    from jax._src.lib import xla_client as xc
    prefill_fn, _ = build_fns(DEFAULT_CONFIG, 0)
    tok = jax.ShapeDtypeStruct((1, DEFAULT_CONFIG.max_seq), jnp.int32)
    length = jax.ShapeDtypeStruct((1,), jnp.int32)
    text = to_hlo_text(jax.jit(prefill_fn).lower(tok, length))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
