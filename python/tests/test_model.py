"""L2 correctness: model shapes, prefill/decode equivalence, and the
determinism the AOT pipeline depends on."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    DEFAULT_CONFIG,
    ModelConfig,
    build_fns,
    empty_cache,
    init_params,
)


@pytest.fixture(scope="module")
def fns():
    return build_fns(DEFAULT_CONFIG, seed=0)


def _pad(tokens_list, cfg):
    b = len(tokens_list)
    out = np.zeros((b, cfg.max_seq), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i, toks in enumerate(tokens_list):
        out[i, : len(toks)] = toks
        lengths[i] = len(toks)
    return jnp.asarray(out), jnp.asarray(lengths)


def test_prefill_shapes(fns):
    prefill, _ = fns
    cfg = DEFAULT_CONFIG
    tokens, lengths = _pad([[1, 2, 3], [4, 5]], cfg)
    logits, cache = prefill(tokens, lengths)
    assert logits.shape == (2, cfg.vocab)
    assert cache.shape == (cfg.n_layers, 2, 2, cfg.max_seq, cfg.n_heads,
                           cfg.d_head)
    assert bool(jnp.isfinite(logits).all())


def test_decode_step_shapes(fns):
    _, decode = fns
    cfg = DEFAULT_CONFIG
    cache = empty_cache(cfg, 4)
    logits, cache2 = decode(
        jnp.array([1, 2, 3, 4], jnp.int32),
        jnp.array([0, 0, 0, 0], jnp.int32),
        cache,
    )
    assert logits.shape == (4, cfg.vocab)
    assert cache2.shape == cache.shape


def test_decode_chain_matches_prefill(fns):
    """Token-by-token decode from an empty cache must produce the same
    final-position logits as one prefill pass (KV-cache correctness)."""
    prefill, decode = fns
    cfg = DEFAULT_CONFIG
    prompts = [[7, 11, 13, 17], [23, 29, 31, 37]]
    tokens, lengths = _pad(prompts, cfg)
    ref_logits, _ = prefill(tokens, lengths)

    cache = empty_cache(cfg, 2)
    logits = None
    for pos in range(4):
        tok = tokens[:, pos]
        logits, cache = decode(tok, jnp.full((2,), pos, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=1e-4, atol=1e-4)


def test_prefill_cache_feeds_decode(fns):
    """Prefill then decode one more token == full decode chain."""
    prefill, decode = fns
    cfg = DEFAULT_CONFIG
    prompt = [3, 1, 4, 1, 5]
    tokens, lengths = _pad([prompt], cfg)
    _, cache = prefill(tokens, lengths)
    nxt = jnp.array([9], jnp.int32)
    logits_a, _ = decode(nxt, jnp.array([5], jnp.int32), cache)

    cache_b = empty_cache(cfg, 1)
    logits_b = None
    for pos, t in enumerate(prompt + [9]):
        logits_b, cache_b = decode(jnp.array([t], jnp.int32),
                                   jnp.array([pos], jnp.int32), cache_b)
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=1e-4, atol=1e-4)


def test_batch_rows_independent(fns):
    _, decode = fns
    cfg = DEFAULT_CONFIG
    cache = empty_cache(cfg, 2)
    toks = jnp.array([5, 200], jnp.int32)
    pos = jnp.array([0, 0], jnp.int32)
    logits, _ = decode(toks, pos, cache)
    # Row 0 alone must match row 0 of the batch.
    c1 = empty_cache(cfg, 1)
    l1, _ = decode.__wrapped__(  # unjitted path would differ; re-jit per B
        init_params(cfg, 0), cfg, toks[:1], pos[:1], c1
    ) if False else (None, None)
    # Use the jitted 2-row call with swapped rows instead: outputs swap too.
    logits_sw, _ = decode(toks[::-1], pos, cache)
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(logits_sw[1]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logits[1]),
                               np.asarray(logits_sw[0]), rtol=1e-5, atol=1e-5)


def test_params_deterministic():
    a = init_params(DEFAULT_CONFIG, seed=0)
    b = init_params(DEFAULT_CONFIG, seed=0)
    np.testing.assert_array_equal(np.asarray(a["tok_emb"]),
                                  np.asarray(b["tok_emb"]))
    c = init_params(DEFAULT_CONFIG, seed=1)
    assert not np.array_equal(np.asarray(a["tok_emb"]),
                              np.asarray(c["tok_emb"]))


def test_custom_config_shapes():
    cfg = ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32,
                      max_seq=8)
    prefill, decode = build_fns(cfg, seed=0)
    tokens = jnp.zeros((1, cfg.max_seq), jnp.int32)
    logits, cache = prefill(tokens, jnp.array([3], jnp.int32))
    assert logits.shape == (1, 32)
    logits2, _ = decode(jnp.array([1], jnp.int32), jnp.array([3], jnp.int32),
                        cache)
    assert logits2.shape == (1, 32)


def test_greedy_generation_is_deterministic(fns):
    prefill, decode = fns
    cfg = DEFAULT_CONFIG

    def gen():
        tokens, lengths = _pad([[1, 2, 3]], cfg)
        logits, cache = prefill(tokens, lengths)
        out = []
        pos = 3
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(10):
            out.append(int(tok[0]))
            logits, cache = decode(tok, jnp.array([pos], jnp.int32), cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            pos += 1
        return out

    assert gen() == gen()
