"""L1 correctness: the Pallas decode-attention kernel vs the pure-jnp
oracle, swept over shapes and dtypes with hypothesis. This is the CORE
correctness signal for the kernel that every decode artifact embeds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention, vmem_report
from compile.kernels.ref import decode_attention_ref


def _inputs(seed, b, s, h, d, dtype):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k0, (b, h, d), dtype)
    k = jax.random.normal(k1, (b, s, h, d), dtype)
    v = jax.random.normal(k2, (b, s, h, d), dtype)
    lengths = jax.random.randint(k3, (b,), 1, s + 1).astype(jnp.int32)
    return q, k, v, lengths


def _tolerance(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(
        rtol=2e-2, atol=2e-2)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    b=st.sampled_from([1, 2, 3, 4, 8]),
    s=st.sampled_from([1, 4, 16, 33, 128]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([4, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_kernel_matches_ref_across_shapes(seed, b, s, h, d, dtype):
    q, k, v, lengths = _inputs(seed, b, s, h, d, dtype)
    out = decode_attention(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    assert out.shape == ref.shape == (b, h, d)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tolerance(dtype))


def test_length_one_attends_only_first_position():
    b, s, h, d = 2, 16, 2, 8
    q, k, v, _ = _inputs(0, b, s, h, d, jnp.float32)
    lengths = jnp.ones((b,), jnp.int32)
    out = decode_attention(q, k, v, lengths)
    # With one valid position the softmax is a delta: output == v[:, 0].
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(v[:, 0]), rtol=1e-5, atol=1e-5)


def test_full_length_equals_unmasked_softmax():
    b, s, h, d = 3, 8, 2, 4
    q, k, v, _ = _inputs(1, b, s, h, d, jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)
    out = decode_attention(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_padding_values_do_not_leak():
    """Garbage beyond `length` must not affect the output."""
    b, s, h, d = 2, 32, 2, 8
    q, k, v, _ = _inputs(2, b, s, h, d, jnp.float32)
    lengths = jnp.array([5, 9], jnp.int32)
    out1 = decode_attention(q, k, v, lengths)
    # Poison the padded region.
    k2 = k.at[:, 10:].set(1e9)
    v2 = v.at[:, 10:].set(-1e9)
    out2 = decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_rows_are_independent():
    b, s, h, d = 4, 16, 2, 8
    q, k, v, lengths = _inputs(3, b, s, h, d, jnp.float32)
    full = decode_attention(q, k, v, lengths)
    for i in range(b):
        row = decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                               lengths[i:i + 1])
        np.testing.assert_allclose(np.asarray(full[i]), np.asarray(row[0]),
                                   rtol=1e-5, atol=1e-5)


def test_softmax_is_convex_combination():
    """Output must lie inside the convex hull of valid V rows (per head/dim
    the value is bounded by min/max over valid positions)."""
    b, s, h, d = 2, 16, 2, 8
    q, k, v, lengths = _inputs(4, b, s, h, d, jnp.float32)
    out = np.asarray(decode_attention(q, k, v, lengths))
    vn = np.asarray(v)
    for i in range(b):
        valid = vn[i, : int(lengths[i])]  # [len, h, d]
        lo = valid.min(axis=0) - 1e-5
        hi = valid.max(axis=0) + 1e-5
        assert (out[i] >= lo).all() and (out[i] <= hi).all()


def test_vmem_report_structure():
    r = vmem_report(8, 128, 4, 16)
    assert r["grid"] == 8
    assert r["vmem_bytes_per_step"] > 0
    assert 0 < r["mxu_tile_utilization"] <= 1.0
    # The staged block must comfortably fit TPU VMEM (~16 MiB).
    assert r["vmem_mib_per_step"] < 16.0


@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_jit_cache_stable_across_batches(b):
    """Each batch variant compiles and runs (the AOT set)."""
    q, k, v, lengths = _inputs(5, b, 128, 4, 16, jnp.float32)
    out = decode_attention(q, k, v, lengths)
    assert out.shape == (b, 4, 16)
    assert bool(jnp.isfinite(out).all())
